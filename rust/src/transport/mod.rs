//! Client↔server transports.
//!
//! * [`chan`] — in-process transport with simnet latency injection: RPCs
//!   really serialize through the wire codec, sleep the modeled one-way
//!   delay each direction, and dispatch into the server. This is what the
//!   figures run on (one OS thread per simulated client process).
//! * [`tcp`] — length-prefixed frames over real TCP for multi-process
//!   deployment (`buffetfs serve` / `buffetfs client`).
//! * [`mux`] — the pipelined multiplexed engine both transports share:
//!   request-id frame headers, the client in-flight table, and the
//!   server-side bounded admission gate (DESIGN.md §9).
//! * [`faulty`] — deterministic seeded fault injection wrapped around any
//!   transport: drops, duplicates, delays and partitions for the chaos
//!   suite (DESIGN.md §11).

pub mod capacity;
pub mod chan;
pub mod faulty;
pub mod mux;
pub mod tcp;

use std::sync::Arc;

use crate::error::{FsError, FsResult};
use crate::wire::{Notify, NotifyAck, Request, Response};

/// A submitted-but-not-yet-claimed RPC (see [`Transport::submit`]).
pub enum Pending {
    /// Lockstep fallback: the request was *not* sent yet; [`Transport::wait`]
    /// executes it as a plain synchronous call. This is what legacy /
    /// downgraded peers get — the schedule degrades to today's N × RTT
    /// without any semantic change.
    Deferred(Request),
    /// True pipelined submission, identified by its wire request id; the
    /// response is routed to the waiter by the demux reader.
    Mux(u64),
}

/// A synchronous RPC endpoint to one server. One [`Transport::call`] is
/// one round trip: the calling thread blocks exactly as the paper's
/// synchronous RPCs do.
///
/// Pipelined transports additionally decouple submission from
/// completion: [`Transport::submit`] puts a request in flight and
/// returns immediately (bounded by the connection's in-flight depth),
/// [`Transport::wait`] claims its response, and [`wait_all`] drives N
/// concurrent RPCs over one connection — wall-clock ≈ max(server work,
/// 1 RTT) instead of N × RTT. The defaults implement the lockstep
/// schedule so every transport (and every downgraded legacy connection)
/// keeps identical semantics.
pub trait Transport: Send + Sync {
    fn call(&self, req: Request) -> FsResult<Response>;

    /// Fire-and-forget (the asynchronous close wrap-up, §3.3). Default
    /// falls back to a synchronous call; real transports override.
    fn call_async(&self, req: Request) -> FsResult<()> {
        self.call(req).map(|_| ())
    }

    /// Submit a request for pipelined completion. The default defers
    /// execution to [`Transport::wait`] (lockstep schedule).
    fn submit(&self, req: Request) -> FsResult<Pending> {
        Ok(Pending::Deferred(req))
    }

    /// Claim the response of a [`Transport::submit`].
    fn wait(&self, pending: Pending) -> FsResult<Response> {
        match pending {
            Pending::Deferred(req) => self.call(req),
            Pending::Mux(id) => Err(FsError::Protocol(format!(
                "transport has no multiplexer for request id {id}"
            ))),
        }
    }

    /// Does `submit` overlap round trips? `false` = lockstep fallback
    /// (callers may skip fan-out entirely to keep RPC counts identical).
    fn is_pipelined(&self) -> bool {
        false
    }
}

/// Claim every submitted response, in submission order. Individual
/// failures don't abort the rest — each slot gets its own result, so a
/// caller can retry precisely.
pub fn wait_all(t: &dyn Transport, pending: Vec<Pending>) -> Vec<FsResult<Response>> {
    pending.into_iter().map(|p| t.wait(p)).collect()
}

/// Server side of the RPC boundary: handles one decoded request.
pub trait Service: Send + Sync {
    fn handle(&self, req: Request) -> Response;
}

impl<F> Service for F
where
    F: Fn(Request) -> Response + Send + Sync,
{
    fn handle(&self, req: Request) -> Response {
        self(req)
    }
}

/// Client side of the push channel: receives invalidation notifications
/// (§3.4) and must answer with an ack.
pub trait NotifySink: Send + Sync {
    fn notify(&self, n: Notify) -> NotifyAck;
}

/// Server handle used to push notifications to one registered client.
pub trait NotifyPush: Send + Sync {
    /// Deliver the notification and block until the client acks (the
    /// server applies permission changes only after all acks, §3.4).
    fn push(&self, n: Notify) -> FsResult<NotifyAck>;
}

pub type SharedTransport = Arc<dyn Transport>;
