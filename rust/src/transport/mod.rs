//! Client↔server transports.
//!
//! * [`chan`] — in-process transport with simnet latency injection: RPCs
//!   really serialize through the wire codec, sleep the modeled one-way
//!   delay each direction, and dispatch into the server. This is what the
//!   figures run on (one OS thread per simulated client process).
//! * [`tcp`] — length-prefixed frames over real TCP for multi-process
//!   deployment (`buffetfs serve` / `buffetfs client`).

pub mod capacity;
pub mod chan;
pub mod tcp;

use std::sync::Arc;

use crate::error::FsResult;
use crate::wire::{Notify, NotifyAck, Request, Response};

/// A synchronous RPC endpoint to one server. One [`Transport::call`] is
/// one round trip: the calling thread blocks exactly as the paper's
/// synchronous RPCs do.
pub trait Transport: Send + Sync {
    fn call(&self, req: Request) -> FsResult<Response>;

    /// Fire-and-forget (the asynchronous close wrap-up, §3.3). Default
    /// falls back to a synchronous call; real transports override.
    fn call_async(&self, req: Request) -> FsResult<()> {
        self.call(req).map(|_| ())
    }
}

/// Server side of the RPC boundary: handles one decoded request.
pub trait Service: Send + Sync {
    fn handle(&self, req: Request) -> Response;
}

impl<F> Service for F
where
    F: Fn(Request) -> Response + Send + Sync,
{
    fn handle(&self, req: Request) -> Response {
        self(req)
    }
}

/// Client side of the push channel: receives invalidation notifications
/// (§3.4) and must answer with an ack.
pub trait NotifySink: Send + Sync {
    fn notify(&self, n: Notify) -> NotifyAck;
}

/// Server handle used to push notifications to one registered client.
pub trait NotifyPush: Send + Sync {
    /// Deliver the notification and block until the client acks (the
    /// server applies permission changes only after all acks, §3.4).
    fn push(&self, n: Notify) -> FsResult<NotifyAck>;
}

pub type SharedTransport = Arc<dyn Transport>;
