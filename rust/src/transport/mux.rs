//! The pipelined multiplexed RPC engine (DESIGN.md §9).
//!
//! Classic BuffetFS transports run strict lockstep: one in-flight
//! request per connection, so a slow `ReadBatch` head-of-line-blocks a
//! 1-byte `Stat` behind it. This module is the shared machinery that
//! decouples *submission* from *completion*:
//!
//! * **Frame header** — pipelined frames prefix the wire payload with
//!   `[magic, version, flags:u16, request_id:u64]`. The magic byte can
//!   never be confused with a legacy frame (legacy payloads start with
//!   a small request/response tag), which is what makes the `Hello`
//!   version handshake — and the sticky downgrade to lockstep framing
//!   against legacy peers — possible.
//! * **[`InflightTable`]** — the client's request-id → waiter-slot map.
//!   `submit` allocates an id under a bounded-depth gate (backpressure),
//!   a demux reader routes each response to its slot, `wait` blocks on
//!   the slot. Completions may arrive in any order; the table counts
//!   out-of-order completions and records the in-flight depth.
//! * **[`Admission`]** — the server side's per-connection in-flight
//!   semaphore: a storm cannot spawn unbounded work, and past the hard
//!   cap requests are shed with [`FsError::Busy`] instead of queued.
//!
//! Both [`super::chan::ChanTransport`] and [`super::tcp::TcpTransport`]
//! drive their pipelined modes through this module; the lockstep
//! fallback lives in the [`super::Transport`] trait's default
//! `submit`/`wait` (deferred execution — same schedule as today).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{FsError, FsResult};
use crate::metrics::RpcMetrics;
use crate::wire::{Request, Response};

/// First byte of a pipelined frame payload. Legacy payloads start with
/// a wire tag (requests 0..=42, responses 0..=18), so this byte is
/// unambiguous: a legacy peer decoding it fails cleanly with "bad
/// request tag 181" and the handshake downgrades.
pub const FRAME_MAGIC: u8 = 0xB5;

/// Protocol version carried in byte 1 of the header. A peer speaking a
/// different version is treated like a legacy peer (downgrade).
pub const MUX_VERSION: u8 = 1;

/// Header bytes: magic, version, flags (u16 LE), request_id (u64 LE).
pub const HEADER_LEN: usize = 12;

/// No flags. The word is reserved for future use (cancellation,
/// priority, streaming); peers must ignore unknown bits.
pub const FLAG_NONE: u16 = 0;

/// The frame carries a trace-context header extension: 16 bytes
/// (`trace_id` u64 LE, `parent_span` u64 LE) between the fixed header
/// and the wire payload. Mux transports ship [`Request::Traced`] this
/// way — header bytes instead of an envelope inside the payload — so
/// tracing adds zero re-encoding of the inner request.
pub const FLAG_TRACE: u16 = 0x1;

/// Byte length of the [`FLAG_TRACE`] header extension.
pub const TRACE_EXT_LEN: usize = 16;

/// Default bound on client-side in-flight requests per connection.
pub const DEFAULT_PIPELINE_DEPTH: usize = 32;

/// Prefix `payload` with the pipelined frame header.
pub fn encode_frame(request_id: u64, flags: u16, payload: &[u8]) -> Vec<u8> {
    encode_frame_ext(request_id, flags, None, payload)
}

/// Like [`encode_frame`], optionally appending the [`FLAG_TRACE`]
/// header extension `(trace_id, parent_span)`. When `trace` is `Some`,
/// the flag bit is set automatically; `None` emits a byte-identical
/// frame to the pre-tracing wire format.
pub fn encode_frame_ext(
    request_id: u64,
    flags: u16,
    trace: Option<(u64, u64)>,
    payload: &[u8],
) -> Vec<u8> {
    let ext = if trace.is_some() { TRACE_EXT_LEN } else { 0 };
    let flags = if trace.is_some() { flags | FLAG_TRACE } else { flags & !FLAG_TRACE };
    let mut out = Vec::with_capacity(HEADER_LEN + ext + payload.len());
    out.push(FRAME_MAGIC);
    out.push(MUX_VERSION);
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&request_id.to_le_bytes());
    if let Some((trace_id, parent_span)) = trace {
        out.extend_from_slice(&trace_id.to_le_bytes());
        out.extend_from_slice(&parent_span.to_le_bytes());
    }
    out.extend_from_slice(payload);
    out
}

/// Is this a pipelined frame of a version we speak?
pub fn is_mux_frame(frame: &[u8]) -> bool {
    frame.len() >= HEADER_LEN && frame[0] == FRAME_MAGIC && frame[1] == MUX_VERSION
}

/// Split a pipelined frame into (request_id, flags, wire payload).
/// Skips (discards) a [`FLAG_TRACE`] extension if present — callers
/// that care about the trace context use [`decode_frame_ext`].
pub fn decode_frame(frame: &[u8]) -> FsResult<(u64, u16, &[u8])> {
    let (id, flags, _trace, body) = decode_frame_ext(frame)?;
    Ok((id, flags, body))
}

/// Split a pipelined frame into (request_id, flags, trace context,
/// wire payload). The trace context is `Some((trace_id, parent_span))`
/// exactly when the sender set [`FLAG_TRACE`].
pub fn decode_frame_ext(frame: &[u8]) -> FsResult<(u64, u16, Option<(u64, u64)>, &[u8])> {
    if frame.len() < HEADER_LEN {
        return Err(FsError::Protocol(format!("short mux frame: {} bytes", frame.len())));
    }
    if frame[0] != FRAME_MAGIC {
        return Err(FsError::Protocol(format!("bad mux magic {:#x}", frame[0])));
    }
    if frame[1] != MUX_VERSION {
        return Err(FsError::Protocol(format!("bad mux version {}", frame[1])));
    }
    let flags = u16::from_le_bytes([frame[2], frame[3]]);
    let id = u64::from_le_bytes(frame[4..12].try_into().expect("12-byte header"));
    if flags & FLAG_TRACE != 0 {
        let end = HEADER_LEN + TRACE_EXT_LEN;
        if frame.len() < end {
            return Err(FsError::Protocol(format!(
                "short trace extension: {} bytes",
                frame.len() - HEADER_LEN
            )));
        }
        let trace_id = u64::from_le_bytes(frame[12..20].try_into().expect("ext"));
        let parent_span = u64::from_le_bytes(frame[20..28].try_into().expect("ext"));
        Ok((id, flags, Some((trace_id, parent_span)), &frame[end..]))
    } else {
        Ok((id, flags, None, &frame[HEADER_LEN..]))
    }
}

/// Peel a [`Request::Traced`] envelope off `req` so a mux transport can
/// carry the trace context in the frame header instead: returns the
/// context (if any) and the bare inner request.
pub fn split_trace(req: Request) -> (Option<(u64, u64)>, Request) {
    match req {
        Request::Traced { trace_id, parent_span, inner } => {
            (Some((trace_id, parent_span)), *inner)
        }
        other => (None, other),
    }
}

// ---------------------------------------------------------------------------
// Client side: the in-flight table
// ---------------------------------------------------------------------------

enum Slot {
    /// A `wait` will claim this response.
    Waiting { seq: u64, op: &'static str, sent: usize, t0: Instant },
    /// Fire-and-forget (`call_async`): completion records metrics and
    /// frees the slot, nobody waits.
    Forgotten { op: &'static str, sent: usize, t0: Instant },
    /// Response arrived before the waiter claimed it.
    Done(FsResult<Response>),
}

struct TableState {
    slots: HashMap<u64, Slot>,
    /// Waiting + Forgotten slots — the depth the admission gate checks,
    /// maintained incrementally so the gate loop is O(1).
    inflight: usize,
    /// Submission sequence numbers still pending, ordered — a completion
    /// with a larger seq than the smallest pending one ran out of order.
    pending_seqs: std::collections::BTreeSet<u64>,
    /// Set once the connection is unusable: every waiter was failed and
    /// every later `begin` refuses fast.
    dead: Option<FsError>,
}

/// The request-id → waiter-slot map with bounded-depth admission.
///
/// Thread model: any number of submitters (`begin` + their own `wait`),
/// one or more completers (the demux reader / chan workers) calling
/// `complete`, and `fail_all` on teardown.
pub struct InflightTable {
    next_id: AtomicU64,
    next_seq: AtomicU64,
    /// In-flight cap (Waiting + Forgotten slots). Settable until first use.
    cap: AtomicUsize,
    state: Mutex<TableState>,
    cv: Condvar,
    metrics: Arc<RpcMetrics>,
}

impl InflightTable {
    pub fn new(cap: usize, metrics: Arc<RpcMetrics>) -> InflightTable {
        InflightTable {
            // id 0 is reserved for the Hello handshake frame
            next_id: AtomicU64::new(1),
            next_seq: AtomicU64::new(0),
            cap: AtomicUsize::new(cap.max(1)),
            state: Mutex::new(TableState {
                slots: HashMap::new(),
                inflight: 0,
                pending_seqs: std::collections::BTreeSet::new(),
                dead: None,
            }),
            cv: Condvar::new(),
            metrics,
        }
    }

    pub fn set_cap(&self, cap: usize) {
        self.cap.store(cap.max(1), Ordering::Relaxed);
    }

    pub fn cap(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    /// Current in-flight count (diagnostics).
    pub fn inflight(&self) -> usize {
        self.state.lock().unwrap().inflight
    }

    fn admit(&self, op: &'static str, sent: usize, forget: bool) -> FsResult<u64> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(e) = &st.dead {
                return Err(e.clone());
            }
            if st.inflight < self.cap.load(Ordering::Relaxed) {
                break;
            }
            st = self.cv.wait(st).unwrap();
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let slot = if forget {
            Slot::Forgotten { op, sent, t0: Instant::now() }
        } else {
            let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
            st.pending_seqs.insert(seq);
            Slot::Waiting { seq, op, sent, t0: Instant::now() }
        };
        st.slots.insert(id, slot);
        st.inflight += 1;
        self.metrics.record_pipeline_submit(st.inflight as u64);
        Ok(id)
    }

    /// Allocate a request id, blocking while the connection is at its
    /// in-flight cap (bounded backpressure).
    pub fn begin(&self, op: &'static str, sent: usize) -> FsResult<u64> {
        self.admit(op, sent, false)
    }

    /// Like [`InflightTable::begin`] but nobody will `wait`: completion
    /// records metrics and frees the slot (fire-and-forget close).
    pub fn begin_forget(&self, op: &'static str, sent: usize) -> FsResult<u64> {
        self.admit(op, sent, true)
    }

    /// Route one response to its slot. Unknown ids (abandoned by a
    /// timed-out waiter) are dropped — routing by id is exactly what
    /// makes a late response harmless here, where it would desynchronize
    /// a lockstep stream.
    pub fn complete(&self, id: u64, result: FsResult<Response>, received: usize) {
        let mut st = self.state.lock().unwrap();
        match st.slots.remove(&id) {
            Some(Slot::Waiting { seq, op, sent, t0 }) => {
                st.pending_seqs.remove(&seq);
                // an earlier-submitted request still pending = we overtook
                if st.pending_seqs.range(..seq).next_back().is_some() {
                    self.metrics.record_ooo_completion();
                }
                self.metrics.record(op, sent, received, t0.elapsed());
                st.inflight -= 1;
                st.slots.insert(id, Slot::Done(result));
            }
            Some(Slot::Forgotten { op, sent, t0 }) => {
                self.metrics.record(op, sent, received, t0.elapsed());
                st.inflight -= 1;
            }
            Some(done @ Slot::Done(_)) => {
                // double completion: keep the first, drop the second
                st.slots.insert(id, done);
            }
            None => {}
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Block until `id` completes. `timeout` is the per-request-id
    /// flavour of the lockstep poison-on-timeout discipline: the slot is
    /// abandoned so a late response is discarded, but the *connection*
    /// stays healthy — demux routing keeps the stream in sync.
    pub fn wait(&self, id: u64, timeout: Option<Duration>) -> FsResult<Response> {
        let deadline = timeout.map(|t| Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            match st.slots.get(&id) {
                Some(Slot::Done(_)) => {
                    let Some(Slot::Done(result)) = st.slots.remove(&id) else { unreachable!() };
                    return result;
                }
                None => {
                    return Err(match &st.dead {
                        Some(e) => e.clone(),
                        None => FsError::Protocol(format!("wait on unknown request id {id}")),
                    })
                }
                Some(_) => {}
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        // abandon: the late reply is dropped on arrival,
                        // and the freed in-flight slot must wake anyone
                        // blocked at the admission gate
                        if let Some(Slot::Waiting { seq, .. }) = st.slots.remove(&id) {
                            st.pending_seqs.remove(&seq);
                            st.inflight -= 1;
                        }
                        drop(st);
                        self.cv.notify_all();
                        return Err(FsError::Transport(format!(
                            "timed out waiting for pipelined response {id}"
                        )));
                    }
                    let (g, _) = self.cv.wait_timeout(st, d - now).unwrap();
                    st = g;
                }
            }
        }
    }

    /// Connection teardown: fail every outstanding waiter with `err` and
    /// refuse all later submissions.
    pub fn fail_all(&self, err: FsError) {
        let mut st = self.state.lock().unwrap();
        st.dead = Some(err.clone());
        let ids: Vec<u64> = st.slots.keys().copied().collect();
        for id in ids {
            match st.slots.remove(&id) {
                Some(Slot::Waiting { .. }) => {
                    st.inflight -= 1;
                    st.slots.insert(id, Slot::Done(Err(err.clone())));
                }
                Some(Slot::Forgotten { .. }) => {
                    st.inflight -= 1; // nobody is waiting
                }
                Some(done @ Slot::Done(_)) => {
                    st.slots.insert(id, done);
                }
                None => {}
            }
        }
        st.pending_seqs.clear();
        drop(st);
        self.cv.notify_all();
    }

    pub fn is_dead(&self) -> bool {
        self.state.lock().unwrap().dead.is_some()
    }
}

// ---------------------------------------------------------------------------
// Worker-pool plumbing shared by both transports
// ---------------------------------------------------------------------------

/// Drain-then-exit work queue for the engine's worker pools (chan's mux
/// workers, the TCP server's per-connection pool): `pop_or_wait` hands
/// out items until `stop` is set AND the queue is empty, so work queued
/// before shutdown still completes. After flipping `stop`, call
/// `wake_all` so parked workers re-check it.
pub struct WorkQueue<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
}

impl<T> Default for WorkQueue<T> {
    fn default() -> Self {
        WorkQueue { q: Mutex::new(VecDeque::new()), cv: Condvar::new() }
    }
}

impl<T> WorkQueue<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&self, item: T) {
        self.q.lock().unwrap().push_back(item);
        self.cv.notify_one();
    }

    /// Next item, blocking while the queue is empty; `None` once `stop`
    /// is set and every queued item was handed out.
    pub fn pop_or_wait(&self, stop: &AtomicBool) -> Option<T> {
        let mut q = self.q.lock().unwrap();
        loop {
            if let Some(item) = q.pop_front() {
                return Some(item);
            }
            if stop.load(Ordering::Acquire) {
                return None;
            }
            q = self.cv.wait(q).unwrap();
        }
    }

    pub fn wake_all(&self) {
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Server side: bounded admission
// ---------------------------------------------------------------------------

/// Per-connection in-flight semaphore: counts admitted (queued +
/// executing) requests; past `cap` the caller sheds with `Busy` instead
/// of queueing. A storm thus costs the server at most `cap` queued
/// requests and `worker_count` executing ones — never unbounded memory
/// or threads.
pub struct Admission {
    cap: usize,
    inflight: AtomicUsize,
}

impl Admission {
    pub fn new(cap: usize) -> Admission {
        Admission { cap: cap.max(1), inflight: AtomicUsize::new(0) }
    }

    /// Try to take a slot; `false` = past the hard cap, shed the request.
    pub fn try_admit(&self) -> bool {
        self.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < self.cap).then_some(n + 1)
            })
            .is_ok()
    }

    /// Release a slot after the response was written.
    pub fn done(&self) {
        self.inflight.fetch_sub(1, Ordering::AcqRel);
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn cap(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Wire;
    use crate::types::Ino;
    use crate::wire::Request;

    fn metrics() -> Arc<RpcMetrics> {
        Arc::new(RpcMetrics::new())
    }

    #[test]
    fn frame_header_roundtrip() {
        let req = Request::GetAttr { ino: Ino::new(0, 0, 7) };
        let payload = req.to_bytes();
        let frame = encode_frame(42, FLAG_NONE, &payload);
        assert!(is_mux_frame(&frame));
        let (id, flags, body) = decode_frame(&frame).unwrap();
        assert_eq!(id, 42);
        assert_eq!(flags, FLAG_NONE);
        assert_eq!(Request::from_bytes(body).unwrap(), req);
    }

    #[test]
    fn legacy_payloads_are_never_mux_frames() {
        // every legacy request/response payload starts with a small tag
        let req = Request::Hello { client: 1 }.to_bytes();
        assert!(!is_mux_frame(&req));
        let resp = Response::Unit.to_bytes();
        assert!(!is_mux_frame(&resp));
        assert!(decode_frame(&req).is_err());
    }

    #[test]
    fn trace_extension_roundtrips() {
        let req = Request::GetAttr { ino: Ino::new(0, 0, 7) };
        let payload = req.to_bytes();
        let frame = encode_frame_ext(9, FLAG_NONE, Some((0xabcd, 0x42)), &payload);
        assert!(is_mux_frame(&frame));
        let (id, flags, trace, body) = decode_frame_ext(&frame).unwrap();
        assert_eq!(id, 9);
        assert_ne!(flags & FLAG_TRACE, 0);
        assert_eq!(trace, Some((0xabcd, 0x42)));
        assert_eq!(Request::from_bytes(body).unwrap(), req);
        // plain decode_frame skips the extension transparently
        let (id2, _, body2) = decode_frame(&frame).unwrap();
        assert_eq!(id2, 9);
        assert_eq!(Request::from_bytes(body2).unwrap(), req);
    }

    #[test]
    fn untraced_frames_are_byte_identical_to_legacy_encoding() {
        let payload = Request::Hello { client: 3 }.to_bytes();
        assert_eq!(
            encode_frame_ext(5, FLAG_NONE, None, &payload),
            encode_frame(5, FLAG_NONE, &payload),
        );
        let truncated = &encode_frame_ext(5, FLAG_NONE, Some((1, 2)), &payload)[..HEADER_LEN + 4];
        assert!(decode_frame_ext(truncated).is_err(), "short trace ext must fail cleanly");
    }

    #[test]
    fn split_trace_peels_the_envelope() {
        let inner = Request::GetAttr { ino: Ino::new(0, 0, 1) };
        let (ctx, bare) = split_trace(Request::Traced {
            trace_id: 11,
            parent_span: 22,
            inner: Box::new(inner.clone()),
        });
        assert_eq!(ctx, Some((11, 22)));
        assert_eq!(bare, inner);
        let (ctx, bare) = split_trace(inner.clone());
        assert_eq!(ctx, None);
        assert_eq!(bare, inner);
    }

    #[test]
    fn wrong_version_downgrades() {
        let mut frame = encode_frame(1, 0, &[8]);
        frame[1] = MUX_VERSION + 1;
        assert!(!is_mux_frame(&frame));
        assert!(decode_frame(&frame).is_err());
    }

    #[test]
    fn out_of_order_completion_routes_by_id() {
        let m = metrics();
        let t = InflightTable::new(8, m.clone());
        let a = t.begin("getattr", 10).unwrap();
        let b = t.begin("read", 10).unwrap();
        assert_eq!(t.inflight(), 2);
        // b completes first: counted as an out-of-order completion
        t.complete(b, Ok(Response::Unit), 4);
        t.complete(a, Ok(Response::Statfs { files: 1, bytes: 2 }), 4);
        assert_eq!(t.wait(b, None).unwrap(), Response::Unit);
        assert_eq!(t.wait(a, None).unwrap(), Response::Statfs { files: 1, bytes: 2 });
        assert_eq!(m.ooo_completions(), 1);
        assert_eq!(m.pipelined_submits(), 2);
        assert_eq!(m.count("getattr"), 1);
        assert_eq!(m.count("read"), 1);
        assert_eq!(t.inflight(), 0);
    }

    #[test]
    fn in_order_completion_is_not_ooo() {
        let m = metrics();
        let t = InflightTable::new(8, m.clone());
        let a = t.begin("getattr", 1).unwrap();
        let b = t.begin("getattr", 1).unwrap();
        t.complete(a, Ok(Response::Unit), 1);
        t.complete(b, Ok(Response::Unit), 1);
        assert_eq!(m.ooo_completions(), 0);
        t.wait(a, None).unwrap();
        t.wait(b, None).unwrap();
    }

    #[test]
    fn depth_gate_blocks_submitters_until_a_completion() {
        let m = metrics();
        let t = Arc::new(InflightTable::new(2, m));
        let a = t.begin("getattr", 1).unwrap();
        let _b = t.begin("getattr", 1).unwrap();
        let t2 = Arc::clone(&t);
        let blocked = std::thread::spawn(move || t2.begin("getattr", 1).unwrap());
        std::thread::sleep(Duration::from_millis(50));
        assert!(!blocked.is_finished(), "third submit must block at depth 2");
        t.complete(a, Ok(Response::Unit), 1);
        t.wait(a, None).unwrap();
        let c = blocked.join().unwrap();
        t.complete(c, Ok(Response::Unit), 1);
        t.wait(c, None).unwrap();
    }

    #[test]
    fn wait_timeout_abandons_slot_and_drops_late_reply() {
        let m = metrics();
        let t = InflightTable::new(8, m);
        let a = t.begin("getattr", 1).unwrap();
        let err = t.wait(a, Some(Duration::from_millis(30))).unwrap_err();
        assert!(matches!(err, FsError::Transport(ref s) if s.contains("timed out")), "{err}");
        assert_eq!(t.inflight(), 0, "abandoned slot freed its in-flight budget");
        // the late reply is discarded, not delivered to anyone
        t.complete(a, Ok(Response::Unit), 1);
        assert!(t.wait(a, None).is_err(), "abandoned id never becomes claimable");
    }

    #[test]
    fn timeout_abandon_wakes_blocked_submitters() {
        let m = metrics();
        let t = Arc::new(InflightTable::new(1, m));
        let a = t.begin("getattr", 1).unwrap();
        let t2 = Arc::clone(&t);
        let blocked = std::thread::spawn(move || t2.begin("getattr", 1).unwrap());
        std::thread::sleep(Duration::from_millis(30));
        assert!(!blocked.is_finished(), "second submit must block at depth 1");
        // the only in-flight request times out: its freed capacity must
        // wake the blocked submitter even though no completion arrives
        t.wait(a, Some(Duration::from_millis(10))).unwrap_err();
        let b = blocked.join().unwrap();
        t.complete(b, Ok(Response::Unit), 1);
        t.wait(b, None).unwrap();
    }

    #[test]
    fn fail_all_poisons_waiters_and_later_submits() {
        let m = metrics();
        let t = InflightTable::new(8, m);
        let a = t.begin("getattr", 1).unwrap();
        t.fail_all(FsError::Transport("conn died".into()));
        assert!(matches!(t.wait(a, None), Err(FsError::Transport(_))));
        assert!(matches!(t.begin("getattr", 1), Err(FsError::Transport(_))));
        assert!(t.is_dead());
    }

    #[test]
    fn forgotten_slots_record_metrics_and_free_capacity() {
        let m = metrics();
        let t = InflightTable::new(1, m.clone());
        let a = t.begin_forget("close", 8).unwrap();
        t.complete(a, Ok(Response::Unit), 4);
        assert_eq!(m.count("close"), 1);
        // capacity freed: another submit is admitted immediately
        let b = t.begin("getattr", 1).unwrap();
        t.complete(b, Ok(Response::Unit), 1);
        t.wait(b, None).unwrap();
    }

    #[test]
    fn work_queue_drains_then_exits() {
        let q: WorkQueue<u32> = WorkQueue::new();
        let stop = AtomicBool::new(false);
        q.push(1);
        q.push(2);
        stop.store(true, Ordering::Release);
        // queued work still comes out after stop; then the pool winds down
        assert_eq!(q.pop_or_wait(&stop), Some(1));
        assert_eq!(q.pop_or_wait(&stop), Some(2));
        assert_eq!(q.pop_or_wait(&stop), None);
    }

    #[test]
    fn admission_sheds_past_hard_cap() {
        let a = Admission::new(2);
        assert!(a.try_admit());
        assert!(a.try_admit());
        assert!(!a.try_admit(), "third request must shed");
        assert_eq!(a.inflight(), 2);
        a.done();
        assert!(a.try_admit());
        a.done();
        a.done();
        assert_eq!(a.inflight(), 0);
    }
}
