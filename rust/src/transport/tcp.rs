//! Real TCP transport: `u32` length-prefixed frames of the wire codec.
//!
//! Used by `buffetfs serve` / `buffetfs client` for actual multi-process
//! deployment. The figures use the in-process [`super::chan`] transport
//! (controlled latency); this module proves the protocol runs over a real
//! socket too and is covered by `rust/tests/tcp_transport.rs`.

use std::io::{Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Wire;
use crate::error::{FsError, FsResult};
use crate::metrics::RpcMetrics;
use crate::transport::{Service, Transport};
use crate::wire::{Request, Response};

const MAX_FRAME: usize = 128 << 20;

/// Default client-side response timeout: a dead peer must surface as a
/// transport error, not hang the calling thread forever.
pub const DEFAULT_CALL_TIMEOUT: Duration = Duration::from_secs(30);

pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> FsResult<()> {
    if payload.len() > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {}", payload.len())));
    }
    let len = (payload.len() as u32).to_le_bytes();
    stream.write_all(&len).map_err(io_err)?;
    stream.write_all(payload).map_err(io_err)?;
    stream.flush().map_err(io_err)
}

pub fn read_frame(stream: &mut TcpStream) -> FsResult<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).map_err(io_err)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {n}")));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf).map_err(io_err)?;
    Ok(buf)
}

/// Server-side frame read with an idle poll: `Ok(None)` when the short
/// poll timeout elapsed with NO byte consumed (idle connection — the
/// caller re-checks its stop flag), `Err` when the peer died or stalled
/// *mid-frame*. A mid-frame timeout desynchronizes the stream (the next
/// read would parse payload bytes as a length header), so — mirroring
/// the client-side poisoning — the connection must be dropped, never
/// resumed.
fn read_frame_idle(stream: &mut TcpStream, idle: std::time::Duration) -> FsResult<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read(&mut len[..1]) {
        Ok(0) => return Err(FsError::Transport("peer closed".into())),
        Ok(_) => {}
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            ) =>
        {
            return Ok(None);
        }
        Err(e) => return Err(io_err(e)),
    }
    // a frame has started: finish it under the generous call timeout
    stream.set_read_timeout(Some(DEFAULT_CALL_TIMEOUT)).ok();
    let result = (|| {
        stream.read_exact(&mut len[1..]).map_err(io_err)?;
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_FRAME {
            return Err(FsError::Protocol(format!("frame too large: {n}")));
        }
        let mut buf = vec![0u8; n];
        stream.read_exact(&mut buf).map_err(io_err)?;
        Ok(buf)
    })();
    stream.set_read_timeout(Some(idle)).ok();
    result.map(Some)
}

fn io_err(e: std::io::Error) -> FsError {
    // normalise both timeout spellings (TimedOut on most platforms,
    // WouldBlock on some) so callers — including the server's idle-poll
    // loop — can match on one phrase
    if matches!(e.kind(), std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock) {
        FsError::Transport(format!("timed out: {e}"))
    } else {
        FsError::Transport(e.to_string())
    }
}

/// Serve `service` on `addr` until `stop` flips. One thread per
/// connection (thread-per-client matches the one-BAgent-per-client model).
pub struct TcpServer {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    pub fn spawn(addr: &str, service: Arc<dyn Service>) -> FsResult<TcpServer> {
        let listener = TcpListener::bind(addr).map_err(io_err)?;
        let local_addr = listener.local_addr().map_err(io_err)?;
        listener.set_nonblocking(true).map_err(io_err)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                let mut conns = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let svc = Arc::clone(&service);
                            let stop3 = Arc::clone(&stop2);
                            conns.push(
                                std::thread::Builder::new()
                                    .name("tcp-conn".into())
                                    .spawn(move || serve_conn(stream, svc, stop3))
                                    .expect("spawn conn thread"),
                            );
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept thread");
        Ok(TcpServer { local_addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(mut stream: TcpStream, service: Arc<dyn Service>, stop: Arc<AtomicBool>) {
    let idle = std::time::Duration::from_millis(100);
    stream.set_read_timeout(Some(idle)).ok();
    // a client that stops draining must not pin this connection thread
    // forever: a timed-out response write drops the connection below
    stream.set_write_timeout(Some(DEFAULT_CALL_TIMEOUT)).ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let frame = match read_frame_idle(&mut stream, idle) {
            Ok(None) => continue,          // idle poll: re-check stop
            Ok(Some(f)) => f,
            Err(_) => return, // peer went away or stalled mid-frame
        };
        let resp = match Request::from_bytes(&frame) {
            Ok(req) => service.handle(req),
            Err(e) => Response::Err(e),
        };
        if write_frame(&mut stream, &resp.to_bytes()).is_err() {
            return;
        }
    }
}

/// Client endpoint over one TCP connection (serialized by a mutex — one
/// in-flight RPC per connection, like a Lustre request slot).
///
/// `TCP_NODELAY` is set on both ends (here and in the server's accept
/// loop): the data plane's small frames must not eat Nagle delays. A
/// configurable read timeout bounds how long a call waits on a dead
/// peer; a timeout leaves the stream desynchronized (the late response
/// may still arrive and would answer the *next* request), so the
/// transport poisons itself — every later call fails fast and the
/// caller must reconnect.
pub struct TcpTransport {
    stream: Mutex<TcpStream>,
    metrics: Arc<RpcMetrics>,
    read_timeout: Option<Duration>,
    poisoned: AtomicBool,
}

impl TcpTransport {
    /// Connect with the [`DEFAULT_CALL_TIMEOUT`] response timeout.
    pub fn connect<A: ToSocketAddrs>(addr: A, metrics: Arc<RpcMetrics>) -> FsResult<Arc<TcpTransport>> {
        Self::connect_with_timeout(addr, Some(DEFAULT_CALL_TIMEOUT), metrics)
    }

    /// Connect with an explicit response timeout (`None` = wait forever,
    /// the pre-timeout behaviour).
    pub fn connect_with_timeout<A: ToSocketAddrs>(
        addr: A,
        read_timeout: Option<Duration>,
        metrics: Arc<RpcMetrics>,
    ) -> FsResult<Arc<TcpTransport>> {
        let stream = TcpStream::connect(addr).map_err(io_err)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(read_timeout).map_err(io_err)?;
        // a peer that stops draining its socket must not hang the writer
        // (and everyone queued behind the stream mutex) forever either
        stream.set_write_timeout(read_timeout).map_err(io_err)?;
        Ok(Arc::new(TcpTransport {
            stream: Mutex::new(stream),
            metrics,
            read_timeout,
            poisoned: AtomicBool::new(false),
        }))
    }

    pub fn read_timeout(&self) -> Option<Duration> {
        self.read_timeout
    }

    /// True after a response timeout: the stream is desynchronized and
    /// this transport must be replaced.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }
}

impl Transport for TcpTransport {
    fn call(&self, req: Request) -> FsResult<Response> {
        if self.poisoned.load(Ordering::Acquire) {
            return Err(FsError::Transport(
                "connection poisoned by an earlier response timeout; reconnect".into(),
            ));
        }
        let op = req.op();
        let t0 = Instant::now();
        let payload = req.to_bytes();
        let mut stream = self.stream.lock().unwrap();
        if let Err(e) = write_frame(&mut stream, &payload) {
            if matches!(&e, FsError::Transport(msg) if msg.contains("timed out")) {
                // a partial frame may be on the wire: desynchronized
                self.poisoned.store(true, Ordering::Release);
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
            return Err(e);
        }
        let frame = match read_frame(&mut stream) {
            Err(FsError::Transport(msg)) if msg.contains("timed out") => {
                // the late response may still arrive and would answer the
                // NEXT request on this stream — poison it so no later
                // call can receive a mismatched frame
                self.poisoned.store(true, Ordering::Release);
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return Err(FsError::Transport(format!(
                    "no response to {op} within {:?}: {msg}",
                    self.read_timeout
                )));
            }
            other => other?,
        };
        drop(stream);
        let resp = Response::from_bytes(&frame)?;
        self.metrics.record(op, payload.len(), frame.len(), t0.elapsed());
        resp.into_result()
    }
}
