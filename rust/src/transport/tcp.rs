//! Real TCP transport: `u32` length-prefixed frames of the wire codec.
//!
//! Used by `buffetfs serve` / `buffetfs client` for actual multi-process
//! deployment. The figures use the in-process [`super::chan`] transport
//! (controlled latency); this module proves the protocol runs over a real
//! socket too and is covered by `rust/tests/tcp_transport.rs` and
//! `rust/tests/pipeline.rs`.
//!
//! Two framings share the socket (DESIGN.md §9):
//!
//! * **Lockstep** (legacy): frame payload = bare wire message, one
//!   in-flight RPC per connection, responses strictly in order.
//! * **Pipelined**: frame payload = `[magic, ver, flags, request_id]` +
//!   wire message ([`mux`]). Responses complete out of order, routed to
//!   waiters by request id; a demux reader thread drains the socket.
//!
//! The mode is negotiated by the first frame: a pipelined client opens
//! with a mux-framed `Hello`. A pipelined server echoes a mux-framed
//! reply and the connection is pipelined for its lifetime; a legacy
//! server fails to decode the magic byte as a request tag and answers a
//! legacy error frame, which the client takes as its cue to **sticky
//! downgrade** to lockstep framing (same pattern as the `ResolvePath`
//! downgrade). A legacy client's first frame has no magic byte, so a new
//! server serves that connection in lockstep mode — both directions
//! interoperate with zero configuration.

use std::io::{Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::codec::Wire;
use crate::error::{FsError, FsResult};
use crate::metrics::RpcMetrics;
use crate::transport::mux::{self, Admission, InflightTable, WorkQueue};
use crate::transport::{Pending, Service, Transport};
use crate::wire::{Request, Response};

const MAX_FRAME: usize = 128 << 20;

/// Default client-side response timeout: a dead peer must surface as a
/// transport error, not hang the calling thread forever.
pub const DEFAULT_CALL_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-connection worker pool size for pipelined connections: how many
/// requests of one connection execute concurrently in the server.
pub const PIPE_CONN_WORKERS: usize = 8;

/// Per-connection admission hard cap (queued + executing). Past it the
/// server sheds with [`FsError::Busy`] instead of queueing — a storm
/// cannot spawn unbounded work (satellite: bounded in-flight admission).
pub const PIPE_ADMIT_CAP: usize = 256;

pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> FsResult<()> {
    if payload.len() > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {}", payload.len())));
    }
    let len = (payload.len() as u32).to_le_bytes();
    stream.write_all(&len).map_err(io_err)?;
    stream.write_all(payload).map_err(io_err)?;
    stream.flush().map_err(io_err)
}

pub fn read_frame(stream: &mut TcpStream) -> FsResult<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).map_err(io_err)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {n}")));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf).map_err(io_err)?;
    Ok(buf)
}

/// Frame read with an idle poll: `Ok(None)` when the short poll timeout
/// elapsed with NO byte consumed (idle connection — the caller re-checks
/// its stop flag), `Err` when the peer died or stalled *mid-frame*. A
/// mid-frame timeout desynchronizes the stream (the next read would
/// parse payload bytes as a length header), so — mirroring the
/// client-side poisoning — the connection must be dropped, never
/// resumed.
fn read_frame_idle(stream: &mut TcpStream, idle: std::time::Duration) -> FsResult<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    match stream.read(&mut len[..1]) {
        Ok(0) => return Err(FsError::Transport("peer closed".into())),
        Ok(_) => {}
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock
            ) =>
        {
            return Ok(None);
        }
        Err(e) => return Err(io_err(e)),
    }
    // a frame has started: finish it under the generous call timeout
    stream.set_read_timeout(Some(DEFAULT_CALL_TIMEOUT)).ok();
    let result = (|| {
        stream.read_exact(&mut len[1..]).map_err(io_err)?;
        let n = u32::from_le_bytes(len) as usize;
        if n > MAX_FRAME {
            return Err(FsError::Protocol(format!("frame too large: {n}")));
        }
        let mut buf = vec![0u8; n];
        stream.read_exact(&mut buf).map_err(io_err)?;
        Ok(buf)
    })();
    stream.set_read_timeout(Some(idle)).ok();
    result.map(Some)
}

fn io_err(e: std::io::Error) -> FsError {
    // normalise both timeout spellings (TimedOut on most platforms,
    // WouldBlock on some) so callers — including the server's idle-poll
    // loop — can match on one phrase
    if matches!(e.kind(), std::io::ErrorKind::TimedOut | std::io::ErrorKind::WouldBlock) {
        FsError::Transport(format!("timed out: {e}"))
    } else {
        FsError::Transport(e.to_string())
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Counters for the server's connection handling (tests / diagnostics).
#[derive(Default)]
pub struct TcpServerStats {
    /// Connections negotiated into pipelined framing.
    pub pipelined_conns: AtomicU64,
    /// Connections served in legacy lockstep framing.
    pub legacy_conns: AtomicU64,
    /// Requests shed with `Busy` past the per-connection admission cap.
    pub shed_busy: AtomicU64,
}

/// Serve `service` on `addr` until `stop` flips. One thread per
/// connection (thread-per-client matches the one-BAgent-per-client
/// model); pipelined connections additionally run a bounded worker pool
/// so independent requests of one client execute concurrently.
pub struct TcpServer {
    pub local_addr: std::net::SocketAddr,
    pub stats: Arc<TcpServerStats>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    pub fn spawn(addr: &str, service: Arc<dyn Service>) -> FsResult<TcpServer> {
        Self::spawn_obs(addr, service, None)
    }

    /// Like [`TcpServer::spawn`], mirroring shed counts into the
    /// server's unified [`crate::obs::ServerMetrics`] registry so a
    /// remote `StatsFetch` sees admission pressure, not just the
    /// process-local [`TcpServerStats`].
    pub fn spawn_obs(
        addr: &str,
        service: Arc<dyn Service>,
        obs: Option<Arc<crate::obs::ServerMetrics>>,
    ) -> FsResult<TcpServer> {
        let listener = TcpListener::bind(addr).map_err(io_err)?;
        let local_addr = listener.local_addr().map_err(io_err)?;
        listener.set_nonblocking(true).map_err(io_err)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let stats = Arc::new(TcpServerStats::default());
        let stats2 = Arc::clone(&stats);
        let accept_thread = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                let mut conns = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let svc = Arc::clone(&service);
                            let stop3 = Arc::clone(&stop2);
                            let st = Arc::clone(&stats2);
                            let ob = obs.clone();
                            conns.push(
                                std::thread::Builder::new()
                                    .name("tcp-conn".into())
                                    .spawn(move || serve_conn(stream, svc, stop3, st, ob))
                                    .expect("spawn conn thread"),
                            );
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept thread");
        Ok(TcpServer { local_addr, stats, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(
    mut stream: TcpStream,
    service: Arc<dyn Service>,
    stop: Arc<AtomicBool>,
    stats: Arc<TcpServerStats>,
    obs: Option<Arc<crate::obs::ServerMetrics>>,
) {
    let idle = std::time::Duration::from_millis(100);
    stream.set_read_timeout(Some(idle)).ok();
    // a client that stops draining must not pin this connection thread
    // forever: a timed-out response write drops the connection below
    stream.set_write_timeout(Some(DEFAULT_CALL_TIMEOUT)).ok();
    // the first frame fixes the connection's framing for its lifetime
    let first = loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        match read_frame_idle(&mut stream, idle) {
            Ok(None) => continue,
            Ok(Some(f)) => break f,
            Err(_) => return,
        }
    };
    if mux::is_mux_frame(&first) {
        stats.pipelined_conns.fetch_add(1, Ordering::Relaxed);
        serve_conn_pipelined(stream, first, service, stop, stats, obs, idle);
    } else {
        stats.legacy_conns.fetch_add(1, Ordering::Relaxed);
        serve_conn_lockstep(stream, first, service, stop, stats, idle);
    }
}

/// Legacy lockstep loop: decode, handle inline, reply in order.
fn serve_conn_lockstep(
    mut stream: TcpStream,
    first: Vec<u8>,
    service: Arc<dyn Service>,
    stop: Arc<AtomicBool>,
    _stats: Arc<TcpServerStats>,
    idle: std::time::Duration,
) {
    let mut frame = first;
    loop {
        let resp = match Request::from_bytes(&frame) {
            Ok(req) => service.handle(req),
            Err(e) => Response::Err(e),
        };
        if write_frame(&mut stream, &resp.to_bytes()).is_err() {
            return;
        }
        frame = loop {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            match read_frame_idle(&mut stream, idle) {
                Ok(None) => continue,          // idle poll: re-check stop
                Ok(Some(f)) => break f,
                Err(_) => return, // peer went away or stalled mid-frame
            }
        };
    }
}

/// Pipelined loop: the reader admits frames into a bounded queue; a
/// fixed worker pool executes them concurrently and writes mux-framed
/// responses (out of order) under a shared write lock.
fn serve_conn_pipelined(
    mut stream: TcpStream,
    first: Vec<u8>,
    service: Arc<dyn Service>,
    stop: Arc<AtomicBool>,
    stats: Arc<TcpServerStats>,
    obs: Option<Arc<crate::obs::ServerMetrics>>,
    idle: std::time::Duration,
) {
    let Ok(writer_stream) = stream.try_clone() else { return };
    let writer = Arc::new(Mutex::new(writer_stream));
    let admission = Arc::new(Admission::new(PIPE_ADMIT_CAP));
    // work items of this connection, bounded by the admission gate
    let queue: Arc<WorkQueue<(u64, Request)>> = Arc::new(WorkQueue::new());
    let conn_stop = Arc::new(AtomicBool::new(false));

    let mut workers = Vec::with_capacity(PIPE_CONN_WORKERS);
    for i in 0..PIPE_CONN_WORKERS {
        let queue = Arc::clone(&queue);
        let writer = Arc::clone(&writer);
        let service = Arc::clone(&service);
        let admission = Arc::clone(&admission);
        let conn_stop = Arc::clone(&conn_stop);
        workers.push(
            std::thread::Builder::new()
                .name(format!("tcp-conn-worker-{i}"))
                .spawn(move || loop {
                    let Some((id, req)) = queue.pop_or_wait(&conn_stop) else { return };
                    let resp = service.handle(req);
                    let frame = mux::encode_frame(id, mux::FLAG_NONE, &resp.to_bytes());
                    let _ = write_frame(&mut writer.lock().unwrap(), &frame);
                    admission.done();
                })
                .expect("spawn conn worker"),
        );
    }

    let dispatch = |frame: Vec<u8>| -> bool {
        let (id, _flags, trace, payload) = match mux::decode_frame_ext(&frame) {
            Ok(parts) => parts,
            Err(_) => return false, // a mid-connection framing switch is fatal
        };
        match Request::from_bytes(payload) {
            Err(e) => {
                let f = mux::encode_frame(id, mux::FLAG_NONE, &Response::Err(e).to_bytes());
                write_frame(&mut writer.lock().unwrap(), &f).is_ok()
            }
            Ok(req) => {
                // a FLAG_TRACE extension is rebuilt into the Traced
                // envelope the dispatch layer understands
                let req = match trace {
                    Some((trace_id, parent_span)) => {
                        Request::Traced { trace_id, parent_span, inner: Box::new(req) }
                    }
                    None => req,
                };
                if admission.try_admit() {
                    queue.push((id, req));
                    true
                } else {
                    // past the hard cap: shed instead of queueing
                    stats.shed_busy.fetch_add(1, Ordering::Relaxed);
                    if let Some(ob) = &obs {
                        ob.sheds.fetch_add(1, Ordering::Relaxed);
                    }
                    let f = mux::encode_frame(
                        id,
                        mux::FLAG_NONE,
                        &Response::Err(FsError::Busy).to_bytes(),
                    );
                    write_frame(&mut writer.lock().unwrap(), &f).is_ok()
                }
            }
        }
    };

    // the handshake Hello rides the normal path: its mux-framed reply is
    // what tells the client this server speaks the pipelined protocol
    let mut alive = dispatch(first);
    while alive && !stop.load(Ordering::Relaxed) {
        match read_frame_idle(&mut stream, idle) {
            Ok(None) => continue,
            Ok(Some(f)) => alive = dispatch(f),
            Err(_) => break,
        }
    }
    // drain-then-exit: queued requests still answer (the client may be
    // gone; writes then fail harmlessly), then the pool winds down
    conn_stop.store(true, Ordering::Release);
    queue.wake_all();
    for w in workers {
        let _ = w.join();
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Lockstep state: the whole connection serialized by a mutex — one
/// in-flight RPC, like a Lustre request slot.
struct Lockstep {
    stream: Mutex<TcpStream>,
}

/// Pipelined state: callers write mux frames under `writer`; one demux
/// reader thread routes responses to [`InflightTable`] slots by id.
struct Pipe {
    writer: Mutex<TcpStream>,
    table: Arc<InflightTable>,
    stop: Arc<AtomicBool>,
    reader: Mutex<Option<std::thread::JoinHandle<()>>>,
}

enum Mode {
    Lockstep(Lockstep),
    Pipelined(Pipe),
}

/// Client endpoint over one TCP connection.
///
/// `TCP_NODELAY` is set on both ends (here and in the server's accept
/// loop): the data plane's small frames must not eat Nagle delays.
///
/// **Lockstep mode** ([`TcpTransport::connect`]): a configurable read
/// timeout bounds how long a call waits on a dead peer; a timeout leaves
/// the stream desynchronized (the late response may still arrive and
/// would answer the *next* request), so the transport poisons itself —
/// every later call fails fast and the caller must reconnect.
///
/// **Pipelined mode** ([`TcpTransport::connect_pipelined`]): the same
/// timeout applies *per request id* — the slot is abandoned and its late
/// response discarded, but demux routing keeps the stream consistent, so
/// the connection itself stays usable. Only a stream-level failure
/// (reader error, timed-out/partial frame *write*) poisons the whole
/// transport, failing every in-flight waiter.
pub struct TcpTransport {
    mode: Mode,
    metrics: Arc<RpcMetrics>,
    read_timeout: Option<Duration>,
    /// Shared with the demux reader thread (which must not hold an `Arc`
    /// of the whole transport — `Drop` joins it).
    poisoned: Arc<AtomicBool>,
}

impl TcpTransport {
    /// Connect in lockstep mode with the [`DEFAULT_CALL_TIMEOUT`].
    pub fn connect<A: ToSocketAddrs>(addr: A, metrics: Arc<RpcMetrics>) -> FsResult<Arc<TcpTransport>> {
        Self::connect_with_timeout(addr, Some(DEFAULT_CALL_TIMEOUT), metrics)
    }

    /// Connect in lockstep mode with an explicit response timeout
    /// (`None` = wait forever, the pre-timeout behaviour).
    pub fn connect_with_timeout<A: ToSocketAddrs>(
        addr: A,
        read_timeout: Option<Duration>,
        metrics: Arc<RpcMetrics>,
    ) -> FsResult<Arc<TcpTransport>> {
        let stream = Self::open_stream(addr, read_timeout)?;
        Ok(Arc::new(TcpTransport {
            mode: Mode::Lockstep(Lockstep { stream: Mutex::new(stream) }),
            metrics,
            read_timeout,
            poisoned: Arc::new(AtomicBool::new(false)),
        }))
    }

    /// Connect and attempt the pipelined `Hello` handshake with default
    /// timeout and depth; a legacy peer sticky-downgrades to lockstep.
    pub fn connect_pipelined<A: ToSocketAddrs>(
        addr: A,
        metrics: Arc<RpcMetrics>,
    ) -> FsResult<Arc<TcpTransport>> {
        Self::connect_pipelined_with(
            addr,
            Some(DEFAULT_CALL_TIMEOUT),
            mux::DEFAULT_PIPELINE_DEPTH,
            metrics,
        )
    }

    /// Connect and attempt the pipelined handshake with an explicit
    /// response timeout and in-flight depth cap.
    pub fn connect_pipelined_with<A: ToSocketAddrs>(
        addr: A,
        read_timeout: Option<Duration>,
        depth: usize,
        metrics: Arc<RpcMetrics>,
    ) -> FsResult<Arc<TcpTransport>> {
        let mut stream = Self::open_stream(addr, read_timeout)?;
        // version handshake: one mux-framed Hello. A pipelined server
        // answers with a mux frame; a legacy server decodes 0xB5 as a
        // request tag, fails, and answers a legacy error frame — the
        // sticky-downgrade cue. Either way exactly one request/response
        // pair crossed the stream, so both modes start in sync.
        let hello = Request::Hello { client: 0 }.to_bytes();
        write_frame(&mut stream, &mux::encode_frame(0, mux::FLAG_NONE, &hello))?;
        let reply = read_frame(&mut stream)?;
        if !mux::is_mux_frame(&reply) {
            // legacy peer: fall back to today's lockstep framing
            return Ok(Arc::new(TcpTransport {
                mode: Mode::Lockstep(Lockstep { stream: Mutex::new(stream) }),
                metrics,
                read_timeout,
                poisoned: Arc::new(AtomicBool::new(false)),
            }));
        }
        let table = Arc::new(InflightTable::new(depth, Arc::clone(&metrics)));
        let stop = Arc::new(AtomicBool::new(false));
        let poisoned = Arc::new(AtomicBool::new(false));
        let mut reader_stream = stream.try_clone().map_err(io_err)?;
        // captured by the reader: NOT the transport itself (Drop joins
        // the reader, which must therefore never hold it alive)
        let rd_table = Arc::clone(&table);
        let rd_stop = Arc::clone(&stop);
        let rd_poisoned = Arc::clone(&poisoned);
        let reader = std::thread::Builder::new()
            .name("tcp-demux".into())
            .spawn(move || {
                let idle = Duration::from_millis(100);
                reader_stream.set_read_timeout(Some(idle)).ok();
                // stream-level failure: nothing can be routed any more
                let die = |err: FsError| {
                    rd_poisoned.store(true, Ordering::Release);
                    rd_table.fail_all(err);
                };
                loop {
                    if rd_stop.load(Ordering::Acquire) {
                        return;
                    }
                    match read_frame_idle(&mut reader_stream, idle) {
                        Ok(None) => continue,
                        Ok(Some(frame)) => match mux::decode_frame(&frame) {
                            Ok((id, _flags, payload)) => {
                                let received = payload.len();
                                rd_table.complete(id, Response::from_bytes(payload), received);
                            }
                            Err(e) => {
                                die(e);
                                let _ = reader_stream.shutdown(std::net::Shutdown::Both);
                                return;
                            }
                        },
                        Err(e) => {
                            if !rd_stop.load(Ordering::Acquire) {
                                die(FsError::Transport(format!(
                                    "demux reader lost the connection: {e}"
                                )));
                                let _ = reader_stream.shutdown(std::net::Shutdown::Both);
                            }
                            return;
                        }
                    }
                }
            })
            .expect("spawn demux reader");
        Ok(Arc::new(TcpTransport {
            mode: Mode::Pipelined(Pipe {
                writer: Mutex::new(stream),
                table,
                stop,
                reader: Mutex::new(Some(reader)),
            }),
            metrics,
            read_timeout,
            poisoned,
        }))
    }

    fn open_stream<A: ToSocketAddrs>(
        addr: A,
        read_timeout: Option<Duration>,
    ) -> FsResult<TcpStream> {
        let stream = TcpStream::connect(addr).map_err(io_err)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(read_timeout).map_err(io_err)?;
        // a peer that stops draining its socket must not hang the writer
        // (and everyone queued behind the stream mutex) forever either
        stream.set_write_timeout(read_timeout).map_err(io_err)?;
        Ok(stream)
    }

    pub fn read_timeout(&self) -> Option<Duration> {
        self.read_timeout
    }

    /// True after a stream-level failure: the connection is
    /// unrecoverable and this transport must be replaced.
    pub fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Did the handshake land in pipelined mode? `false` after a sticky
    /// downgrade against a legacy peer (or for plain `connect`).
    pub fn is_pipelined_mode(&self) -> bool {
        matches!(self.mode, Mode::Pipelined(_))
    }

    /// Stream-level failure in pipelined mode: fail every waiter, refuse
    /// later submissions, tear the socket down.
    fn poison_pipe(&self, err: FsError) {
        self.poisoned.store(true, Ordering::Release);
        if let Mode::Pipelined(pipe) = &self.mode {
            pipe.table.fail_all(err);
            if let Ok(w) = pipe.writer.lock() {
                let _ = w.shutdown(std::net::Shutdown::Both);
            }
        }
    }

    fn call_lockstep(&self, ls: &Lockstep, req: Request) -> FsResult<Response> {
        let op = req.op();
        let t0 = Instant::now();
        let payload = req.to_bytes();
        let mut stream = ls.stream.lock().unwrap();
        if let Err(e) = write_frame(&mut stream, &payload) {
            if matches!(&e, FsError::Transport(msg) if msg.contains("timed out")) {
                // a partial frame may be on the wire: desynchronized
                self.poisoned.store(true, Ordering::Release);
                let _ = stream.shutdown(std::net::Shutdown::Both);
            }
            return Err(e);
        }
        let frame = match read_frame(&mut stream) {
            Err(FsError::Transport(msg)) if msg.contains("timed out") => {
                // the late response may still arrive and would answer the
                // NEXT request on this stream — poison it so no later
                // call can receive a mismatched frame
                self.poisoned.store(true, Ordering::Release);
                let _ = stream.shutdown(std::net::Shutdown::Both);
                return Err(FsError::Transport(format!(
                    "no response to {op} within {:?}: {msg}",
                    self.read_timeout
                )));
            }
            other => other?,
        };
        drop(stream);
        let resp = Response::from_bytes(&frame)?;
        self.metrics.record(op, payload.len(), frame.len(), t0.elapsed());
        resp.into_result()
    }

    /// Put one mux frame on the wire for an already-allocated id. A
    /// timed-out or partial write desynchronizes the *outbound* stream,
    /// which no amount of demuxing can repair — whole-connection poison.
    fn send_frame(
        &self,
        pipe: &Pipe,
        id: u64,
        trace: Option<(u64, u64)>,
        payload: &[u8],
    ) -> FsResult<()> {
        let frame = mux::encode_frame_ext(id, mux::FLAG_NONE, trace, payload);
        let mut w = pipe.writer.lock().unwrap();
        if let Err(e) = write_frame(&mut w, &frame) {
            drop(w);
            self.poison_pipe(e.clone());
            return Err(e);
        }
        Ok(())
    }

    fn submit_pipelined(&self, pipe: &Pipe, req: Request) -> FsResult<u64> {
        if self.poisoned.load(Ordering::Acquire) {
            return Err(FsError::Transport(
                "connection poisoned by an earlier stream failure; reconnect".into(),
            ));
        }
        // a Traced envelope rides in the frame header, not the payload
        let (trace, req) = mux::split_trace(req);
        let payload = req.to_bytes();
        let id = pipe.table.begin(req.op(), payload.len())?;
        self.send_frame(pipe, id, trace, &payload)?;
        Ok(id)
    }
}

impl Transport for TcpTransport {
    fn call(&self, req: Request) -> FsResult<Response> {
        if self.poisoned.load(Ordering::Acquire) {
            return Err(FsError::Transport(
                "connection poisoned by an earlier response timeout; reconnect".into(),
            ));
        }
        match &self.mode {
            Mode::Lockstep(ls) => self.call_lockstep(ls, req),
            // submit + wait: the pipelined call composes with concurrent
            // submitters instead of serializing behind a stream mutex
            Mode::Pipelined(pipe) => {
                let op = req.op();
                let id = self.submit_pipelined(pipe, req)?;
                match pipe.table.wait(id, self.read_timeout) {
                    Err(FsError::Transport(msg)) if msg.contains("timed out") => {
                        Err(FsError::Transport(format!(
                            "no response to {op} within {:?}: {msg}",
                            self.read_timeout
                        )))
                    }
                    other => other?.into_result(),
                }
            }
        }
    }

    fn call_async(&self, req: Request) -> FsResult<()> {
        match &self.mode {
            Mode::Lockstep(_) => self.call(req).map(|_| ()),
            Mode::Pipelined(pipe) => {
                if self.poisoned.load(Ordering::Acquire) {
                    return Err(FsError::Transport("connection poisoned".into()));
                }
                let (trace, req) = mux::split_trace(req);
                let payload = req.to_bytes();
                // fire-and-forget: completion frees the slot, nobody waits
                let id = pipe.table.begin_forget(req.op(), payload.len())?;
                self.send_frame(pipe, id, trace, &payload)
            }
        }
    }

    fn submit(&self, req: Request) -> FsResult<Pending> {
        match &self.mode {
            // downgraded/legacy connections keep the lockstep schedule
            Mode::Lockstep(_) => Ok(Pending::Deferred(req)),
            Mode::Pipelined(pipe) => Ok(Pending::Mux(self.submit_pipelined(pipe, req)?)),
        }
    }

    fn wait(&self, pending: Pending) -> FsResult<Response> {
        match (pending, &self.mode) {
            (Pending::Deferred(req), _) => self.call(req),
            (Pending::Mux(id), Mode::Pipelined(pipe)) => {
                pipe.table.wait(id, self.read_timeout)?.into_result()
            }
            (Pending::Mux(id), Mode::Lockstep(_)) => Err(FsError::Protocol(format!(
                "mux pending {id} on a lockstep connection"
            ))),
        }
    }

    fn is_pipelined(&self) -> bool {
        self.is_pipelined_mode()
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        if let Mode::Pipelined(pipe) = &self.mode {
            pipe.stop.store(true, Ordering::Release);
            if let Ok(w) = pipe.writer.lock() {
                let _ = w.shutdown(std::net::Shutdown::Both);
            }
            if let Some(r) = pipe.reader.lock().unwrap().take() {
                let _ = r.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Reconnecting client wrapper
// ---------------------------------------------------------------------------

/// Redial policy for a [`ReconnectTransport`].
#[derive(Clone, Copy, Debug)]
pub struct ReconnectConfig {
    /// Attempt the pipelined handshake on every (re)dial.
    pub pipelined: bool,
    /// Response timeout handed to each dialed connection.
    pub read_timeout: Option<Duration>,
    /// Redial attempts per recovery round before the failure surfaces.
    pub max_redials: u32,
    /// Backoff before the first redial attempt; doubled per attempt
    /// (plus an equal-sized random jitter) up to `backoff_cap`.
    pub backoff: Duration,
    pub backoff_cap: Duration,
}

impl Default for ReconnectConfig {
    fn default() -> ReconnectConfig {
        ReconnectConfig {
            pipelined: false,
            read_timeout: Some(DEFAULT_CALL_TIMEOUT),
            max_redials: 4,
            backoff: Duration::from_millis(20),
            backoff_cap: Duration::from_secs(1),
        }
    }
}

/// Address-retaining wrapper that un-dead-ends a broken [`TcpTransport`].
///
/// A poisoned connection fails every later call *by design* (the stream
/// is desynchronized and must be dropped); before this wrapper the only
/// recovery was tearing the whole client down. The wrapper keeps the
/// peer address, notices the poison marker — or any transport-level
/// call failure, e.g. a cleanly closed peer, which never poisons a
/// lockstep stream — and redials with bounded, jittered exponential
/// backoff. Callers keep their `SharedTransport` handle across the
/// swap. It deliberately does NOT re-issue the failed request: retry
/// policy is idempotence-aware and belongs to the caller (the agent's
/// failover path), not the byte pipe.
pub struct ReconnectTransport {
    addr: String,
    cfg: ReconnectConfig,
    metrics: Arc<RpcMetrics>,
    inner: std::sync::RwLock<Arc<TcpTransport>>,
    /// Serializes redials so a stampede of failed callers dials once.
    redial: Mutex<()>,
    /// Set by any transport-level call failure; cleared by a successful
    /// redial. Covers dead-but-unpoisoned streams (peer closed). A
    /// transient per-request timeout on a still-healthy pipelined
    /// connection also lands here — costing one needless redial, which
    /// beats dead-ending.
    dead: AtomicBool,
    /// Jitter state (cheap xorshift*; racy updates only add entropy).
    jitter: AtomicU64,
}

impl ReconnectTransport {
    /// Dial `addr` once eagerly (so configuration errors surface at
    /// startup) and wrap the connection for automatic redial.
    pub fn connect(
        addr: &str,
        cfg: ReconnectConfig,
        metrics: Arc<RpcMetrics>,
    ) -> FsResult<Arc<ReconnectTransport>> {
        let first = Self::dial(addr, &cfg, &metrics)?;
        Ok(Arc::new(ReconnectTransport {
            addr: addr.to_string(),
            cfg,
            metrics,
            inner: std::sync::RwLock::new(first),
            redial: Mutex::new(()),
            dead: AtomicBool::new(false),
            jitter: AtomicU64::new(0x2545_F491_4F6C_DD1D),
        }))
    }

    fn dial(
        addr: &str,
        cfg: &ReconnectConfig,
        metrics: &Arc<RpcMetrics>,
    ) -> FsResult<Arc<TcpTransport>> {
        if cfg.pipelined {
            TcpTransport::connect_pipelined_with(
                addr,
                cfg.read_timeout,
                mux::DEFAULT_PIPELINE_DEPTH,
                Arc::clone(metrics),
            )
        } else {
            TcpTransport::connect_with_timeout(addr, cfg.read_timeout, Arc::clone(metrics))
        }
    }

    /// The connection currently behind the wrapper (tests/diagnostics).
    pub fn current(&self) -> Arc<TcpTransport> {
        Arc::clone(&self.inner.read().unwrap())
    }

    pub fn peer_addr(&self) -> &str {
        &self.addr
    }

    fn next_jitter_us(&self, bound_us: u64) -> u64 {
        let mut x = self.jitter.load(Ordering::Relaxed);
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.jitter.store(x, Ordering::Relaxed);
        x.wrapping_mul(0x2545_F491_4F6C_DD1D) % bound_us.max(1)
    }

    /// A live connection: the current one unless it is poisoned or a
    /// call on it failed at the transport level — then redial, bounded.
    fn live(&self) -> FsResult<Arc<TcpTransport>> {
        let t = self.current();
        if !t.is_poisoned() && !self.dead.load(Ordering::Acquire) {
            return Ok(t);
        }
        let _g = self.redial.lock().unwrap();
        // another caller may have finished the redial while we queued
        let t = self.current();
        if !t.is_poisoned() && !self.dead.load(Ordering::Acquire) {
            return Ok(t);
        }
        let mut last = FsError::Transport(format!("{} unreachable", self.addr));
        for attempt in 0..self.cfg.max_redials {
            let base = self
                .cfg
                .backoff
                .saturating_mul(1u32 << attempt.min(16))
                .min(self.cfg.backoff_cap);
            let jitter =
                Duration::from_micros(self.next_jitter_us(base.as_micros().max(1) as u64));
            std::thread::sleep(base + jitter);
            match Self::dial(&self.addr, &self.cfg, &self.metrics) {
                Ok(fresh) => {
                    *self.inner.write().unwrap() = Arc::clone(&fresh);
                    self.dead.store(false, Ordering::Release);
                    self.metrics.record_reconnect();
                    return Ok(fresh);
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    fn note<T>(&self, r: FsResult<T>) -> FsResult<T> {
        if matches!(&r, Err(FsError::Transport(_))) {
            self.dead.store(true, Ordering::Release);
        }
        r
    }
}

impl Transport for ReconnectTransport {
    fn call(&self, req: Request) -> FsResult<Response> {
        let t = self.live()?;
        self.note(t.call(req))
    }

    fn call_async(&self, req: Request) -> FsResult<()> {
        let t = self.live()?;
        self.note(t.call_async(req))
    }

    fn submit(&self, req: Request) -> FsResult<Pending> {
        let t = self.live()?;
        self.note(t.submit(req))
    }

    fn wait(&self, pending: Pending) -> FsResult<Response> {
        // NOT `live()`: a pending belongs to the connection that issued
        // it. If that connection died, its in-flight table already
        // failed every waiter; if a redial swapped connections between
        // submit and wait, the fresh table cleanly rejects the unknown
        // id — an error either way, never a hang or a mismatched reply.
        self.note(self.current().wait(pending))
    }

    fn is_pipelined(&self) -> bool {
        self.current().is_pipelined()
    }
}
