//! Real TCP transport: `u32` length-prefixed frames of the wire codec.
//!
//! Used by `buffetfs serve` / `buffetfs client` for actual multi-process
//! deployment. The figures use the in-process [`super::chan`] transport
//! (controlled latency); this module proves the protocol runs over a real
//! socket too and is covered by `rust/tests/tcp_transport.rs`.

use std::io::{Read, Write as IoWrite};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::codec::Wire;
use crate::error::{FsError, FsResult};
use crate::metrics::RpcMetrics;
use crate::transport::{Service, Transport};
use crate::wire::{Request, Response};

const MAX_FRAME: usize = 128 << 20;

pub fn write_frame(stream: &mut TcpStream, payload: &[u8]) -> FsResult<()> {
    if payload.len() > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {}", payload.len())));
    }
    let len = (payload.len() as u32).to_le_bytes();
    stream.write_all(&len).map_err(io_err)?;
    stream.write_all(payload).map_err(io_err)?;
    stream.flush().map_err(io_err)
}

pub fn read_frame(stream: &mut TcpStream) -> FsResult<Vec<u8>> {
    let mut len = [0u8; 4];
    stream.read_exact(&mut len).map_err(io_err)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME {
        return Err(FsError::Protocol(format!("frame too large: {n}")));
    }
    let mut buf = vec![0u8; n];
    stream.read_exact(&mut buf).map_err(io_err)?;
    Ok(buf)
}

fn io_err(e: std::io::Error) -> FsError {
    FsError::Transport(e.to_string())
}

/// Serve `service` on `addr` until `stop` flips. One thread per
/// connection (thread-per-client matches the one-BAgent-per-client model).
pub struct TcpServer {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    pub fn spawn(addr: &str, service: Arc<dyn Service>) -> FsResult<TcpServer> {
        let listener = TcpListener::bind(addr).map_err(io_err)?;
        let local_addr = listener.local_addr().map_err(io_err)?;
        listener.set_nonblocking(true).map_err(io_err)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept_thread = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                let mut conns = Vec::new();
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            stream.set_nonblocking(false).ok();
                            stream.set_nodelay(true).ok();
                            let svc = Arc::clone(&service);
                            let stop3 = Arc::clone(&stop2);
                            conns.push(
                                std::thread::Builder::new()
                                    .name("tcp-conn".into())
                                    .spawn(move || serve_conn(stream, svc, stop3))
                                    .expect("spawn conn thread"),
                            );
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
                for c in conns {
                    let _ = c.join();
                }
            })
            .expect("spawn accept thread");
        Ok(TcpServer { local_addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn serve_conn(mut stream: TcpStream, service: Arc<dyn Service>, stop: Arc<AtomicBool>) {
    stream
        .set_read_timeout(Some(std::time::Duration::from_millis(100)))
        .ok();
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let frame = match read_frame(&mut stream) {
            Ok(f) => f,
            Err(FsError::Transport(msg))
                if msg.contains("timed out") || msg.contains("would block") || msg.contains("Resource temporarily") =>
            {
                continue;
            }
            Err(_) => return, // peer went away
        };
        let resp = match Request::from_bytes(&frame) {
            Ok(req) => service.handle(req),
            Err(e) => Response::Err(e),
        };
        if write_frame(&mut stream, &resp.to_bytes()).is_err() {
            return;
        }
    }
}

/// Client endpoint over one TCP connection (serialized by a mutex — one
/// in-flight RPC per connection, like a Lustre request slot).
pub struct TcpTransport {
    stream: Mutex<TcpStream>,
    metrics: Arc<RpcMetrics>,
}

impl TcpTransport {
    pub fn connect<A: ToSocketAddrs>(addr: A, metrics: Arc<RpcMetrics>) -> FsResult<Arc<TcpTransport>> {
        let stream = TcpStream::connect(addr).map_err(io_err)?;
        stream.set_nodelay(true).ok();
        Ok(Arc::new(TcpTransport { stream: Mutex::new(stream), metrics }))
    }
}

impl Transport for TcpTransport {
    fn call(&self, req: Request) -> FsResult<Response> {
        let op = req.op();
        let t0 = Instant::now();
        let payload = req.to_bytes();
        let mut stream = self.stream.lock().unwrap();
        write_frame(&mut stream, &payload)?;
        let frame = read_frame(&mut stream)?;
        drop(stream);
        let resp = Response::from_bytes(&frame)?;
        self.metrics.record(op, payload.len(), frame.len(), t0.elapsed());
        resp.into_result()
    }
}
