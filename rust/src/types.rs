//! Core value types shared across every layer of BuffetFS.
//!
//! The paper's namespace design (§3.2): an inode number is a triple
//! `(hostID, version, fileID)` — the host identifies the BServer that
//! stores the file, the version records server incarnations (reboot /
//! restore), and the fileID is unique per server. A client can locate any
//! file from its inode alone, which is what makes the decentralized
//! (MDS-less) namespace possible.

use std::fmt;

/// Identifies a BServer (or an MDS/OSS in the baseline cluster).
pub type HostId = u16;
/// Server incarnation number (bumped on reboot/restore, §3.2).
pub type Version = u16;
/// Per-server unique file identifier.
pub type FileId = u64;

/// The BuffetFS inode number: `(hostID, version, fileID)` packed.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ino {
    pub host: HostId,
    pub version: Version,
    pub file: FileId,
}

impl Ino {
    pub const fn new(host: HostId, version: Version, file: FileId) -> Self {
        Ino { host, version, file }
    }

    /// Pack into a single u128 (wire/storage form).
    pub fn pack(self) -> u128 {
        ((self.host as u128) << 80) | ((self.version as u128) << 64) | self.file as u128
    }

    pub fn unpack(raw: u128) -> Self {
        Ino {
            host: (raw >> 80) as u16,
            version: (raw >> 64) as u16,
            file: raw as u64,
        }
    }
}

impl fmt::Debug for Ino {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}v{}:{}", self.host, self.version, self.file)
    }
}

impl fmt::Display for Ino {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

/// Access mask bits, octal-class layout (matches `python/compile/kernels/ref.py`).
pub const R_OK: u8 = 4;
pub const W_OK: u8 = 2;
pub const X_OK: u8 = 1;

/// Requested access as a bitmask of `R_OK | W_OK | X_OK`.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct AccessMask(pub u8);

impl AccessMask {
    pub const READ: AccessMask = AccessMask(R_OK);
    pub const WRITE: AccessMask = AccessMask(W_OK);
    pub const EXEC: AccessMask = AccessMask(X_OK);
    pub const RW: AccessMask = AccessMask(R_OK | W_OK);
    pub const NONE: AccessMask = AccessMask(0);

    pub fn contains(self, other: AccessMask) -> bool {
        self.0 & other.0 == other.0
    }
    pub fn union(self, other: AccessMask) -> AccessMask {
        AccessMask(self.0 | other.0)
    }
}

impl fmt::Debug for AccessMask {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let m = self.0;
        write!(
            f,
            "{}{}{}",
            if m & R_OK != 0 { 'r' } else { '-' },
            if m & W_OK != 0 { 'w' } else { '-' },
            if m & X_OK != 0 { 'x' } else { '-' }
        )
    }
}

/// What kind of object an inode refers to.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum FileKind {
    Regular,
    Directory,
    Symlink,
}

impl FileKind {
    pub fn to_wire(self) -> u8 {
        match self {
            FileKind::Regular => 0,
            FileKind::Directory => 1,
            FileKind::Symlink => 2,
        }
    }
    pub fn from_wire(v: u8) -> Option<Self> {
        Some(match v {
            0 => FileKind::Regular,
            1 => FileKind::Directory,
            2 => FileKind::Symlink,
            _ => return None,
        })
    }
}

/// Permission bits (low 12: setuid/setgid/sticky + rwxrwxrwx; only the low
/// 9 participate in access checks, mirroring the kernels).
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct FileMode(pub u16);

impl FileMode {
    pub fn bits(self) -> u16 {
        self.0 & 0o7777
    }
    pub fn owner_class(self) -> u8 {
        ((self.0 >> 6) & 7) as u8
    }
    pub fn group_class(self) -> u8 {
        ((self.0 >> 3) & 7) as u8
    }
    pub fn other_class(self) -> u8 {
        (self.0 & 7) as u8
    }
    pub fn any_exec(self) -> bool {
        self.0 & 0o111 != 0
    }
}

impl fmt::Debug for FileMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "0o{:03o}", self.0)
    }
}

/// A credential: who is asking. The primary gid is, by convention, also
/// present in `groups` (mirrors the kernel oracles).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Credentials {
    pub uid: u32,
    pub gid: u32,
    pub groups: Vec<u32>,
}

impl Credentials {
    pub fn new(uid: u32, gid: u32) -> Self {
        Credentials { uid, gid, groups: vec![gid] }
    }
    pub fn with_groups(uid: u32, gid: u32, mut extra: Vec<u32>) -> Self {
        let mut groups = vec![gid];
        groups.append(&mut extra);
        Credentials { uid, gid, groups }
    }
    pub fn root() -> Self {
        Credentials::new(0, 0)
    }
    pub fn in_group(&self, gid: u32) -> bool {
        self.groups.iter().any(|&g| g == gid)
    }
}

/// The 10 extra bytes BuffetFS stores per directory entry (§3.2): enough
/// for a child's permission check without touching its inode —
/// mode:u16 + uid:u32 + gid:u32 = 10 bytes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct PermBlob {
    pub mode: FileMode,
    pub uid: u32,
    pub gid: u32,
}

pub const PERM_BLOB_BYTES: usize = 10;

impl PermBlob {
    pub fn new(mode: u16, uid: u32, gid: u32) -> Self {
        PermBlob { mode: FileMode(mode), uid, gid }
    }

    pub fn to_bytes(self) -> [u8; PERM_BLOB_BYTES] {
        let mut b = [0u8; PERM_BLOB_BYTES];
        b[0..2].copy_from_slice(&self.mode.0.to_le_bytes());
        b[2..6].copy_from_slice(&self.uid.to_le_bytes());
        b[6..10].copy_from_slice(&self.gid.to_le_bytes());
        b
    }

    pub fn from_bytes(b: &[u8; PERM_BLOB_BYTES]) -> Self {
        PermBlob {
            mode: FileMode(u16::from_le_bytes([b[0], b[1]])),
            uid: u32::from_le_bytes([b[2], b[3], b[4], b[5]]),
            gid: u32::from_le_bytes([b[6], b[7], b[8], b[9]]),
        }
    }
}

/// open(2)-style flags, reduced to what the paper's I/O path exercises.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct OpenFlags {
    pub read: bool,
    pub write: bool,
    pub create: bool,
    pub truncate: bool,
    pub append: bool,
    /// O_DIRECT-style: bypass the client data plane (page cache,
    /// read-ahead, write-back) — every read/write is one synchronous RPC,
    /// exactly the pre-datapath schedule. Keeps baseline comparisons
    /// honest and gives applications an explicit coherence escape hatch.
    pub direct: bool,
}

impl OpenFlags {
    pub const RDONLY: OpenFlags = OpenFlags {
        read: true,
        write: false,
        create: false,
        truncate: false,
        append: false,
        direct: false,
    };
    pub const WRONLY: OpenFlags = OpenFlags {
        read: false,
        write: true,
        create: false,
        truncate: false,
        append: false,
        direct: false,
    };
    pub const RDWR: OpenFlags = OpenFlags {
        read: true,
        write: true,
        create: false,
        truncate: false,
        append: false,
        direct: false,
    };

    pub fn with_create(mut self) -> Self {
        self.create = true;
        self
    }
    pub fn with_truncate(mut self) -> Self {
        self.truncate = true;
        self
    }
    pub fn with_append(mut self) -> Self {
        self.append = true;
        self
    }
    pub fn with_direct(mut self) -> Self {
        self.direct = true;
        self
    }

    /// The access mask the permission check must grant (leaf of the walk).
    pub fn access_mask(self) -> AccessMask {
        let mut m = 0;
        if self.read {
            m |= R_OK;
        }
        if self.write || self.truncate || self.append {
            m |= W_OK;
        }
        AccessMask(m)
    }

    pub fn to_wire(self) -> u8 {
        (self.read as u8)
            | (self.write as u8) << 1
            | (self.create as u8) << 2
            | (self.truncate as u8) << 3
            | (self.append as u8) << 4
            | (self.direct as u8) << 5
    }
    pub fn from_wire(v: u8) -> Self {
        OpenFlags {
            read: v & 1 != 0,
            write: v & 2 != 0,
            create: v & 4 != 0,
            truncate: v & 8 != 0,
            append: v & 16 != 0,
            direct: v & 32 != 0,
        }
    }
}

/// Inode attributes as reported to clients (front-end metadata view).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Attr {
    pub ino: Ino,
    pub kind: FileKind,
    pub perm: PermBlob,
    pub size: u64,
    pub nlink: u32,
    /// seconds since epoch (paper: atime/mtime/ctime mirrored front/back)
    pub atime: u64,
    pub mtime: u64,
    pub ctime: u64,
}

/// A directory entry as stored in the DirTable and shipped over the wire:
/// name + child inode + the 10-byte permission blob + kind.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DirEntry {
    pub name: String,
    pub ino: Ino,
    pub kind: FileKind,
    pub perm: PermBlob,
}

/// Client identifier (one BAgent per client node).
pub type ClientId = u32;
/// Per-client process identifier (the BAgent keeps one context per pid).
pub type Pid = u32;
/// File descriptor handed to applications by BLib.
pub type Fd = i32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ino_pack_roundtrip() {
        let cases = [
            Ino::new(0, 0, 0),
            Ino::new(1, 2, 3),
            Ino::new(u16::MAX, u16::MAX, u64::MAX),
            Ino::new(42, 7, 0xdead_beef_cafe),
        ];
        for ino in cases {
            assert_eq!(Ino::unpack(ino.pack()), ino);
        }
    }

    #[test]
    fn perm_blob_is_ten_bytes_and_roundtrips() {
        let p = PermBlob::new(0o754, 1000, 2000);
        let b = p.to_bytes();
        assert_eq!(b.len(), PERM_BLOB_BYTES);
        assert_eq!(PermBlob::from_bytes(&b), p);
    }

    #[test]
    fn mode_classes() {
        let m = FileMode(0o754);
        assert_eq!(m.owner_class(), 7);
        assert_eq!(m.group_class(), 5);
        assert_eq!(m.other_class(), 4);
        assert!(m.any_exec());
        assert!(!FileMode(0o644).any_exec());
    }

    #[test]
    fn open_flags_roundtrip_and_mask() {
        for raw in 0..64u8 {
            let f = OpenFlags::from_wire(raw);
            assert_eq!(OpenFlags::from_wire(f.to_wire()), f);
        }
        assert_eq!(OpenFlags::RDONLY.access_mask(), AccessMask::READ);
        assert_eq!(OpenFlags::RDWR.access_mask(), AccessMask::RW);
        assert_eq!(OpenFlags::WRONLY.with_append().access_mask(), AccessMask::WRITE);
        // O_DIRECT is a transport hint, not an access bit
        assert_eq!(OpenFlags::RDONLY.with_direct().access_mask(), AccessMask::READ);
        assert!(OpenFlags::from_wire(OpenFlags::RDWR.with_direct().to_wire()).direct);
    }

    #[test]
    fn access_mask_contains() {
        assert!(AccessMask::RW.contains(AccessMask::READ));
        assert!(!AccessMask::READ.contains(AccessMask::WRITE));
        assert!(AccessMask::NONE.contains(AccessMask::NONE));
    }

    #[test]
    fn credentials_groups_include_primary() {
        let c = Credentials::with_groups(5, 10, vec![20, 30]);
        assert!(c.in_group(10));
        assert!(c.in_group(30));
        assert!(!c.in_group(40));
    }
}
