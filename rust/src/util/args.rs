//! Tiny CLI argument parser (clap stand-in): `--key value`, `--flag`,
//! positional args, with typed getters and a generated usage line.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    let v = it.next().unwrap();
                    out.opts.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.pos.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn key_value_pairs() {
        let a = parse("--rtt 200 --procs=8 run");
        assert_eq!(a.get_u64("rtt", 0), 200);
        assert_eq!(a.get_usize("procs", 0), 8);
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn bare_flags() {
        let a = parse("--verbose --rtt 5 --dry-run");
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("rtt"));
        assert_eq!(a.get_u64("rtt", 0), 5);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("mode", "buffet"), "buffet");
        assert_eq!(a.get_f64("zipf", 0.9), 0.9);
    }
}
