//! Log-bucketed latency histogram (hdrhistogram stand-in).
//!
//! Buckets are powers-of-two with 16 linear sub-buckets each, covering
//! 1 ns .. ~1.2 h with ≤ 6.25 % relative error — plenty for figure
//! regeneration.

const SUB: usize = 16;
const BUCKETS: usize = 42;

#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { counts: vec![0; BUCKETS * SUB], total: 0, sum: 0, min: u64::MAX, max: 0 }
    }

    fn index(value: u64) -> usize {
        if value < SUB as u64 {
            return value as usize;
        }
        let msb = 63 - value.leading_zeros() as usize;
        let bucket = msb - 3; // values < 16 handled above; bucket 1 starts at 16
        let shift = msb - 4; // sub-bucket width = 2^(msb)/16
        let sub = ((value >> shift) & (SUB as u64 - 1)) as usize;
        (bucket * SUB + sub).min(BUCKETS * SUB - 1)
    }

    pub fn record(&mut self, value_ns: u64) {
        self.counts[Self::index(value_ns)] += 1;
        self.total += 1;
        self.sum += value_ns as u128;
        self.min = self.min.min(value_ns);
        self.max = self.max.max(value_ns);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    /// Representative value of bucket i (lower edge).
    fn bucket_value(i: usize) -> u64 {
        let bucket = i / SUB;
        let sub = (i % SUB) as u64;
        if bucket == 0 {
            return sub;
        }
        let base = 1u64 << (bucket + 3);
        base + sub * (base / SUB as u64)
    }

    pub fn percentile(&self, p: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_value(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 { 0 } else { self.min }
    }
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// "mean p50 p99 max" in microseconds, for table rows.
    pub fn summary_us(&self) -> String {
        format!(
            "mean={:9.1}us p50={:9.1}us p99={:9.1}us max={:9.1}us n={}",
            self.mean() / 1e3,
            self.percentile(50.0) as f64 / 1e3,
            self.percentile(99.0) as f64 / 1e3,
            self.max() as f64 / 1e3,
            self.total
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::XorShift;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0);
    }

    #[test]
    fn exact_for_small_values() {
        let mut h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5] {
            h.record(v);
        }
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 5);
        assert_eq!(h.percentile(50.0), 3);
        assert!((h.mean() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_relative_error_bounded() {
        let mut h = Histogram::new();
        let mut r = XorShift::new(1);
        let mut vals: Vec<u64> = (0..100_000).map(|_| r.range(100, 10_000_000)).collect();
        for &v in &vals {
            h.record(v);
        }
        vals.sort();
        for p in [50.0, 90.0, 99.0, 99.9] {
            let exact = vals[((p / 100.0) * vals.len() as f64) as usize - 1];
            let est = h.percentile(p);
            let rel = (est as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.10, "p{p}: est={est} exact={exact} rel={rel}");
        }
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(100);
        b.record(200);
        b.record(300);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 300);
        assert_eq!(a.min(), 100);
    }

    #[test]
    fn monotone_percentiles() {
        let mut h = Histogram::new();
        let mut r = XorShift::new(2);
        for _ in 0..10_000 {
            h.record(r.range(1, 1_000_000));
        }
        let mut last = 0;
        for p in [1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let v = h.percentile(p);
            assert!(v >= last, "p{p} went backwards: {v} < {last}");
            last = v;
        }
    }
}
