//! Minimal stderr logger backing the `log` facade (env_logger stand-in).
//! Level comes from `BUFFETFS_LOG` (error|warn|info|debug|trace).

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{lvl}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent; respects `BUFFETFS_LOG`).
pub fn init() {
    let level = match std::env::var("BUFFETFS_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("info") => LevelFilter::Info,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        _ => LevelFilter::Warn,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
