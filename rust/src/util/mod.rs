//! Small self-contained utilities (the offline crate universe has no rand,
//! no env_logger, no hdrhistogram — these are the minimal stand-ins).

pub mod args;
pub mod hist;
pub mod logger;
pub mod rng;

/// Sleep with microsecond precision. `thread::sleep` overshoots by
/// ~50–150µs on Linux (timer slack), which at simulated-RPC scale (100µs
/// one-way) would distort every figure; for short waits we spin the tail.
pub fn precise_sleep(d: std::time::Duration) {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::{Duration, Instant};
    if d.is_zero() {
        return;
    }
    // `thread::sleep` overshoots by a scheduler-dependent amount (~60µs
    // idle, worse under load). We keep a global EWMA of the observed
    // overshoot and subtract it from the requested sleep, then absorb the
    // (small) residue in a bounded yield loop. This stays accurate on a
    // loaded single-core box without burning the CPU that the simulated
    // "processes" need — a pure spin or a long yield tail would serialize
    // the whole simulation behind the sleeper.
    static OVERSHOOT_NS: AtomicU64 = AtomicU64::new(60_000);
    let deadline = Instant::now() + d;
    let est = Duration::from_nanos(OVERSHOOT_NS.load(Ordering::Relaxed));
    if d > est + Duration::from_micros(20) {
        let t0 = Instant::now();
        let ask = d - est;
        std::thread::sleep(ask);
        let over = Instant::now().duration_since(t0).saturating_sub(ask);
        // EWMA, α = 1/8
        let prev = OVERSHOOT_NS.load(Ordering::Relaxed);
        let next = prev - prev / 8 + (over.as_nanos() as u64) / 8;
        OVERSHOOT_NS.store(next.clamp(1_000, 2_000_000), Ordering::Relaxed);
    }
    // bounded residue: yields hand the core over when others are runnable
    while Instant::now() < deadline {
        std::thread::yield_now();
    }
}

/// Current unix time in seconds (inode timestamps).
pub fn unix_now() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
