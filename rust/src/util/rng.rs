//! Deterministic xorshift64* RNG — seeds make every simulation, workload
//! and property sweep reproducible without the `rand` crate.

#[derive(Clone, Debug)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        XorShift { state: seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).max(1) }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Zipf-ish rank sampler over `[0, n)` with exponent `s` via inverse
    /// CDF on a harmonic approximation (good enough for workload skew).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n > 0);
        if s <= 0.0 {
            return self.below(n);
        }
        // rejection-free approximate inverse: u ~ U(0,1],
        // rank ≈ n^(u) scaled — cheap, heavy-tailed, deterministic.
        let u = 1.0 - self.f64();
        let x = ((n as f64).powf(1.0 - s.min(0.99)) * u.powf(-1.0)).min(n as f64);
        // map heavy tail onto [0, n)
        let r = (x.ln() / (n as f64).ln().max(1e-9) * n as f64) as u64;
        r.min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = { let mut r = XorShift::new(7); (0..8).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = XorShift::new(7); (0..8).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        let c: Vec<u64> = { let mut r = XorShift::new(8); (0..8).map(|_| r.next_u64()).collect() };
        assert_ne!(a, c);
    }

    #[test]
    fn below_in_range() {
        let mut r = XorShift::new(3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
            let v = r.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = XorShift::new(11);
        let mut sum = 0.0;
        for _ in 0..50_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 50_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_in_range() {
        let mut r = XorShift::new(9);
        for _ in 0..10_000 {
            assert!(r.zipf(1000, 0.9) < 1000);
        }
        // s=0 degenerates to uniform
        for _ in 0..1000 {
            assert!(r.zipf(10, 0.0) < 10);
        }
    }
}
