//! The BuffetFS wire protocol: every client↔server message.
//!
//! One protocol serves both BuffetFS and the Lustre baselines so the
//! comparison isolates the *schedule* of RPCs, not their encoding:
//!
//! * BuffetFS never sends [`Request::Open`]; the open record (paper
//!   §3.3 "Step 2") travels as the [`OpenCtx`] piggy-backed on the first
//!   [`Request::Read`]/[`Request::Write`] (the `incomplete-opened` flag).
//! * The Lustre baselines always send [`Request::Open`] to the MDS; in
//!   DoM mode the open reply carries the file data inline.
//! * [`Notify`] messages flow server→client on the push channel
//!   (permission-change invalidations, §3.4).

use crate::codec::{Dec, Enc, Wire};
use crate::error::{FsError, FsResult};
use crate::types::{
    Attr, ClientId, Credentials, DirEntry, FileKind, HostId, Ino, OpenFlags,
};

/// Sentinel data generation meaning "no expectation": a [`Request::ReadBatch`]
/// / [`Request::WriteBatch`] carrying it skips the server-side staleness
/// check, and a client holding no cached pages sends it.
pub const NO_GEN: u64 = u64::MAX;

/// Deferred-open context: piggy-backs "Step 2 of open()" onto the first
/// read/write of an incomplete-opened file (paper Fig. 2(b), b-2).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpenCtx {
    pub client: ClientId,
    /// Client-chosen handle; identifies this open in the opened-file list.
    pub handle: u64,
    pub flags: OpenFlags,
    pub cred: Credentials,
}

/// One contiguous byte range of a [`Request::ReadBatch`] (page-aligned on
/// the client, but the server imposes no alignment).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ByteRange {
    pub off: u64,
    pub len: u32,
}

/// One contiguous dirty extent of a [`Request::WriteBatch`] — exactly the
/// bytes the application wrote, never read-modify-written page padding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WriteSeg {
    pub off: u64,
    pub data: Vec<u8>,
}

/// A directory permission lease, stamped onto every dirfd-relative
/// request (the handle-first client API): the handle's node plus the
/// server lease epoch observed when the lease was granted. The server
/// rejects a mismatching epoch with [`crate::error::FsError::StaleLease`]
/// so the client re-resolves the handle and retries once; revocation
/// (`chmod`/`chown`/`rename`) bumps the epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LeaseStamp {
    pub node: Ino,
    pub epoch: u64,
}

/// One speculated mutation inside a [`Request::MetaBatch`]. `op_id` is
/// the client's per-op exactly-once stamp (same id space as
/// [`Request::Stamped`]): the server dedups each item individually
/// against its ledger, so a blind batch retry after failover re-applies
/// nothing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BatchItem {
    pub op_id: u64,
    pub op: BatchOp,
}

/// The mutation kinds a speculation chain can carry. All are relative
/// to the batch's leased directory; `Rename` moves within it (the
/// speculation layer only batches same-directory renames — cross-dir
/// renames are barriers). `Close` retires the open record of a
/// speculatively created file whose data already flushed, so the
/// wrap-up RPC rides the batch instead of going out per file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BatchOp {
    Create { name: String, mode: u16, kind: FileKind },
    Mkdir { name: String, mode: u16 },
    Unlink { name: String },
    Rmdir { name: String },
    Rename { sname: String, dname: String },
    Close { ino: Ino, handle: u64 },
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// Resolve one name in a directory (baseline path walk).
    Lookup { dir: Ino, name: String, cred: Credentials },
    /// Fetch a whole directory (BuffetFS cache population). When
    /// `register` is set the server records this client in the directory's
    /// cache registry (§3.4) so later permission changes invalidate it.
    ReadDir { dir: Ino, client: ClientId, register: bool, cred: Credentials },
    GetAttr { ino: Ino },
    /// Baseline-only: server-side open (permission check + open record).
    /// `want_inline` asks a DoM MDS to return small file data inline.
    Open { ino: Ino, flags: OpenFlags, cred: Credentials, client: ClientId, handle: u64, want_inline: bool },
    Read { ino: Ino, off: u64, len: u32, open_ctx: Option<OpenCtx> },
    Write { ino: Ino, off: u64, data: Vec<u8>, open_ctx: Option<OpenCtx> },
    /// Asynchronous close wrap-up (removes the opened-file entry).
    Close { ino: Ino, client: ClientId, handle: u64 },
    Create { dir: Ino, name: String, mode: u16, kind: FileKind, cred: Credentials, client: ClientId },
    Mkdir { dir: Ino, name: String, mode: u16, cred: Credentials },
    Unlink { dir: Ino, name: String, cred: Credentials },
    Rmdir { dir: Ino, name: String, cred: Credentials },
    Rename { sdir: Ino, sname: String, ddir: Ino, dname: String, cred: Credentials },
    /// Permission change: triggers the §3.4 invalidate-then-apply protocol.
    Chmod { ino: Ino, mode: u16, cred: Credentials },
    Chown { ino: Ino, uid: u32, gid: u32, cred: Credentials },
    Truncate { ino: Ino, size: u64, cred: Credentials },
    Statfs { host: HostId },
    /// Client liveness/registration handshake (gives the server the push
    /// channel for invalidations).
    Hello { client: ClientId },
    /// Server↔server: run the §3.4 invalidate-and-ack barrier for a
    /// directory this server owns (called by the server owning a child
    /// inode whose dirent lives here).
    PrepareInvalidate { dir: Ino },
    /// Server↔server: sync a dirent's 10-byte perm blob after a remote
    /// child's chmod/chown.
    UpdateDirentPerm { dir: Ino, name: String, perm: crate::types::PermBlob },
    /// Server↔server: allocate an object here whose dirent lives on the
    /// calling (directory-owning) server — decentralized placement.
    CreateOrphan { parent: Ino, name: String, mode: u16, kind: FileKind, uid: u32, gid: u32 },
    /// Server↔server: drop a local object after its remote dirent was
    /// unlinked.
    DropObject { ino: Ino },
    /// Lustre intent open: lookup + permission check + open record in ONE
    /// MDS round trip (how real Lustre opens a path whose dentry is not
    /// cached). The reply's `attr.ino` doubles as the dentry.
    OpenByName { dir: Ino, name: String, flags: OpenFlags, cred: Credentials, client: ClientId, handle: u64, want_inline: bool },
    /// Batched cold-path walk: starting at `base` (a directory this
    /// server owns), walk as many of `components` as this server can in
    /// ONE round trip, returning every traversed directory's full listing
    /// (entries **with** their 10-byte perm blobs) so the client installs
    /// the whole prefix at once. The walk stops at a server boundary
    /// (continuation in [`Response::Walked::next`]), at a missing name
    /// (the returned listing is the client's authoritative local ENOENT),
    /// at a non-directory, or at a directory the cred cannot read.
    ResolvePath { base: Ino, components: Vec<String>, client: ClientId, register: bool, cred: Credentials },
    /// Grant/refresh a directory permission lease (handle API): the
    /// reply carries the directory's attr plus the server's current
    /// lease epoch, and the client is registered for §3.4 invalidation
    /// pushes on the directory. Requires X (traversal capability).
    Lease { node: Ino, client: ClientId, cred: Credentials },
    /// Dirfd-relative open — the handle API's remote fallback (e.g. an
    /// X-only directory whose listing the cred may not READ). The open
    /// record is written eagerly (not deferred), under `handle`.
    /// `want_inline` asks for the file's contents (up to the server's
    /// inline limit) piggy-backed on the reply (data plane, §7).
    OpenAt { lease: LeaseStamp, name: String, flags: OpenFlags, cred: Credentials, client: ClientId, handle: u64, want_inline: bool },
    /// Dirfd-relative stat: lookup `name` under the leased directory and
    /// return its attr (forwarded to the owning peer for remote objects).
    StatAt { lease: LeaseStamp, name: String, cred: Credentials },
    /// Dirfd-relative ReadDir of the leased directory itself.
    ReadDirAt { lease: LeaseStamp, client: ClientId, register: bool, cred: Credentials },
    /// Dirfd-relative create.
    CreateAt { lease: LeaseStamp, name: String, mode: u16, kind: FileKind, cred: Credentials, client: ClientId },
    /// Dirfd-relative mkdir.
    MkdirAt { lease: LeaseStamp, name: String, mode: u16, cred: Credentials },
    /// Dirfd-relative unlink.
    UnlinkAt { lease: LeaseStamp, name: String, cred: Credentials },
    /// Dirfd-relative rmdir.
    RmdirAt { lease: LeaseStamp, name: String, cred: Credentials },
    /// Dirfd-relative rename between two leased directories (both must
    /// live on this server). Applying it bumps BOTH lease epochs.
    RenameAt { src: LeaseStamp, sname: String, dst: LeaseStamp, dname: String, cred: Credentials },
    /// Data plane: fetch several byte ranges of one file in ONE round
    /// trip (cache miss + read-ahead window). `known_gen` is the data
    /// generation of the pages the client already holds ([`NO_GEN`] when
    /// it holds none): a mismatch means some other writer got in between,
    /// and the server answers [`crate::error::FsError::StaleData`] so the
    /// client drops its pages and retries once. `register` enrols the
    /// client for data-invalidation pushes on this file.
    ReadBatch {
        ino: Ino,
        ranges: Vec<ByteRange>,
        known_gen: u64,
        client: ClientId,
        register: bool,
        open_ctx: Option<OpenCtx>,
    },
    /// Data plane: flush a batch of coalesced dirty extents in ONE round
    /// trip (write-back buffering turns N small `write()`s into one of
    /// these). `base_gen` ([`NO_GEN`] = no expectation) guards the
    /// client's cached read view: if the server's generation moved, it
    /// answers `StaleData` *without applying*, the client drops its page
    /// cache and retries the flush unguarded (the segments are exclusively
    /// application-written bytes, so the retry is always safe).
    WriteBatch {
        ino: Ino,
        segs: Vec<WriteSeg>,
        base_gen: u64,
        client: ClientId,
        register: bool,
        open_ctx: Option<OpenCtx>,
    },
    /// Primary→backup replication: a run of raw write-ahead journal
    /// frames (`[len][crc][payload]`, see `server::journal`). The backup
    /// applies them via the replay paths, appends them byte-identical to
    /// its own journal, fsyncs, and answers [`Response::Unit`] — that
    /// ack is the primary's past-the-backup commit point.
    JournalShip { frames: Vec<u8> },
    /// Exactly-once envelope around a mutating request: `(client, op_id)`
    /// names the operation uniquely for this client, so a server that
    /// already executed it (the reply was lost, or a failover re-sent it
    /// to the standby that had the journal shipped) answers the cached
    /// original reply from its dedup ledger instead of applying twice.
    /// `ack_upto` is the client's acknowledged low-water mark: every op
    /// id ≤ it completed at the client and will never be retried, so the
    /// server may prune those ledger entries. Negotiated by the sticky
    /// downgrade machinery: an old server rejects the unknown tag with a
    /// protocol error and the agent permanently falls back to plain
    /// (non-retryable) mutations, exactly like `ResolvePath`.
    Stamped { client: ClientId, op_id: u64, ack_upto: u64, inner: Box<Request> },
    /// Standby catch-up: read a chunk of the primary's write-ahead
    /// journal starting at `(gen, offset)`. The primary answers
    /// [`Response::JournalChunk`] with whole frames (≤ `max_bytes`, but
    /// always at least one frame); a generation mismatch resets the
    /// cursor to the current segment's start — safe because every
    /// segment opens with a full checkpoint snapshot of server state.
    JournalFetch { gen: u64, offset: u64, max_bytes: u32 },
    /// Fetch the server's view of the directory placement map when the
    /// client's cached copy (version `since`) went stale — answered with
    /// [`Response::PlacementMap`]. Any server can answer: the map is
    /// shared cluster state flipped at migration commit.
    PlacementFetch { since: u64 },
    /// Admin/balancer→server: migrate the subtree rooted at `dir` (a
    /// directory this server owns) to `target`, live. `grace` bounds how
    /// many straggler ops the source forwards per migrated file after
    /// the placement flip before answering hard
    /// [`crate::error::FsError::WrongServer`] redirects.
    MigrateSubtree { dir: Ino, target: HostId, grace: u32 },
    /// Server↔server: the migration payload — a run of raw journal
    /// frames (snapshot of the subtree, its lease epochs, and the
    /// source's dedup ledger) the target adopts, applies and journals.
    SubtreeImport { frames: Vec<u8> },
    /// Server↔server: a rename moved `ino`'s dirent on the sending
    /// server; the owner re-points its inode's parent/name bookkeeping
    /// so `parent_of` and later perm dirent-syncs stay honest.
    UpdateParentMeta { ino: Ino, parent: Ino, name: String },
    /// Remote telemetry scrape: ask the server for its unified metrics
    /// snapshot (see [`crate::obs::ServerMetrics`]). `sections` is a
    /// bitmask of `crate::obs::SEC_*` selecting which JSON sections to
    /// assemble; `trace_id` ≠ 0 additionally returns every server-side
    /// span of that trace (for `buffetfs trace`). Answered with
    /// [`Response::Stats`]. Old servers reject the unknown tag with a
    /// protocol error — the CLI reports that plainly.
    StatsFetch { sections: u32, trace_id: u64 },
    /// Tracing envelope: carries the client's trace context so the
    /// server records its spans under the same `trace_id`, causally
    /// linked beneath `parent_span`. Always the *outermost* envelope
    /// (wraps `Stamped`, never the reverse) so a legacy peer fails on
    /// this tag first and the agent can sticky-downgrade tracing alone,
    /// exactly like the `Stamped`/`ResolvePath` negotiation. Mux
    /// transports strip it into a frame-header extension instead of
    /// shipping the envelope bytes.
    Traced { trace_id: u64, parent_span: u64, inner: Box<Request> },
    /// Speculation drain: apply a dependency-ordered run of metadata
    /// mutations against ONE leased directory atomically under its file
    /// lock (DESIGN.md §14). Items apply in order; the first failure
    /// stops the batch — its slot in [`Response::Batch`] carries the
    /// error and later items are NOT attempted (the client rolls back
    /// dependents and re-flushes the independent tail). Each item is
    /// individually stamped (`BatchItem::op_id`, same ledger as
    /// [`Request::Stamped`]) so failover retries are exactly-once;
    /// `ack_upto` prunes the ledger like a `Stamped` envelope. Old
    /// servers reject the unknown tag and the agent sticky-downgrades
    /// to sequential per-op flushes.
    MetaBatch {
        lease: LeaseStamp,
        client: ClientId,
        ack_upto: u64,
        cred: Credentials,
        ops: Vec<BatchItem>,
    },
}

/// One override row of the directory placement map: the subtree rooted
/// at `dir` is owned by `owner` (everything else lives with its ino's
/// birth host).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlacementEntry {
    pub dir: Ino,
    pub owner: HostId,
}

/// One directory listing returned by a [`Request::ResolvePath`] walk:
/// the directory's own attr (its perm blob) plus all entries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WalkedDir {
    pub attr: Attr,
    pub entries: Vec<DirEntry>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    Entry(DirEntry),
    /// Directory attr + all entries (each carrying its 10-byte PermBlob).
    Entries { dir: Attr, entries: Vec<DirEntry> },
    AttrR(Attr),
    /// Baseline open reply; `inline` carries DoM data when present.
    Opened { attr: Attr, inline: Option<Vec<u8>> },
    Data { data: Vec<u8>, size: u64 },
    Written { written: u32, new_size: u64 },
    Created(DirEntry),
    Statfs { files: u64, bytes: u64 },
    Unit,
    Err(FsError),
    /// Reply to [`Request::ResolvePath`]: listings of every directory the
    /// walk traversed (in walk order, starting with `base` itself),
    /// `walked` = how many of the requested components were consumed, and
    /// `next` = the directory to continue from when the walk crossed a
    /// server boundary in the decentralized namespace.
    Walked { dirs: Vec<WalkedDir>, walked: u32, next: Option<Ino> },
    /// Reply to [`Request::Lease`]: the directory's attr plus the
    /// server's current lease epoch for it.
    Leased { attr: Attr, epoch: u64 },
    /// Reply to [`Request::ReadBatch`]: one data segment per requested
    /// range (short at EOF), the file's current size, and the data
    /// generation the segments were read under (stamped onto the
    /// client's pages).
    DataBatch { segs: Vec<Vec<u8>>, size: u64, data_gen: u64 },
    /// Reply to [`Request::WriteBatch`]: total bytes applied, resulting
    /// file size, and the post-write data generation.
    WrittenBatch { written: u64, new_size: u64, data_gen: u64 },
    /// Reply to an open with `want_inline` from a data-plane client: the
    /// attr, the file's data generation, and — when the file fits the
    /// server's inline limit — its entire contents, so open + full read
    /// of a small file costs zero data RPCs. (The classic [`Response::Opened`]
    /// stays untouched for the Lustre-DoM baseline.)
    OpenedInline { attr: Attr, data_gen: u64, data: Option<Vec<u8>> },
    /// Reply to [`Request::JournalFetch`]: raw journal frames from the
    /// primary's segment `gen`, ending at byte `offset` (the standby's
    /// next cursor). `more` = the segment has further frames to pull.
    JournalChunk { gen: u64, offset: u64, frames: Vec<u8>, more: bool },
    /// Reply to [`Request::PlacementFetch`]: the full override table at
    /// `version` (small: one row per migrated subtree root).
    PlacementMap { version: u64, entries: Vec<PlacementEntry> },
    /// Reply to [`Request::MigrateSubtree`]: the handoff committed —
    /// `files` objects moved, and the placement map now reads
    /// `map_version`.
    Migrated { files: u64, map_version: u64 },
    /// Reply to [`Request::StatsFetch`]: the requested metric sections
    /// rendered as one JSON object, plus raw server-side spans (the
    /// requested trace's, or the slow-op drain) so the CLI can render
    /// causal trees without a JSON parser.
    Stats { json: String, spans: Vec<crate::obs::Span> },
    /// Reply to [`Request::MetaBatch`]: one reply per attempted item,
    /// in order. A failed item's slot is [`Response::Err`]; a reply
    /// shorter than the request's `ops` means the tail was never
    /// attempted (the server stops at the first failure).
    Batch(Vec<Response>),
}

/// Server→client push messages (the §3.4 consistency protocol).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Notify {
    /// Invalidate cached tree nodes for these directories (and every
    /// child entry hanging off them). Client must ack before the server
    /// applies the permission change.
    Invalidate { seq: u64, dirs: Vec<Ino> },
    /// Data plane: another writer bumped `ino`'s data generation to
    /// `gen` — drop every cached page of it (dirty write-back extents
    /// survive; they are the client's own bytes). Pushed over the same
    /// §3.4 channel, before the write is applied.
    DataInvalidate { seq: u64, ino: Ino, gen: u64 },
}

/// Client→server ack for a [`Notify::Invalidate`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NotifyAck {
    pub client: ClientId,
    pub seq: u64,
}

impl Request {
    /// Short op name for metrics.
    pub fn op(&self) -> &'static str {
        match self {
            Request::Lookup { .. } => "lookup",
            Request::ReadDir { .. } => "readdir",
            Request::GetAttr { .. } => "getattr",
            Request::Open { .. } => "open",
            Request::Read { .. } => "read",
            Request::Write { .. } => "write",
            Request::Close { .. } => "close",
            Request::Create { .. } => "create",
            Request::Mkdir { .. } => "mkdir",
            Request::Unlink { .. } => "unlink",
            Request::Rmdir { .. } => "rmdir",
            Request::Rename { .. } => "rename",
            Request::Chmod { .. } => "chmod",
            Request::Chown { .. } => "chown",
            Request::Truncate { .. } => "truncate",
            Request::Statfs { .. } => "statfs",
            Request::Hello { .. } => "hello",
            Request::PrepareInvalidate { .. } => "invalidate",
            Request::UpdateDirentPerm { .. } => "invalidate",
            Request::CreateOrphan { .. } => "create",
            Request::DropObject { .. } => "unlink",
            Request::OpenByName { .. } => "open",
            Request::ResolvePath { .. } => "resolve",
            Request::Lease { .. } => "lease",
            Request::OpenAt { .. } => "open",
            Request::StatAt { .. } => "getattr",
            Request::ReadDirAt { .. } => "readdir",
            Request::CreateAt { .. } => "create",
            Request::MkdirAt { .. } => "mkdir",
            Request::UnlinkAt { .. } => "unlink",
            Request::RmdirAt { .. } => "rmdir",
            Request::RenameAt { .. } => "rename",
            Request::ReadBatch { .. } => "read",
            Request::WriteBatch { .. } => "write",
            Request::JournalShip { .. } => "replicate",
            Request::Stamped { inner, .. } => inner.op(),
            Request::JournalFetch { .. } => "replicate",
            Request::PlacementFetch { .. } => "placement",
            Request::MigrateSubtree { .. } => "migrate",
            Request::SubtreeImport { .. } => "migrate",
            Request::UpdateParentMeta { .. } => "rename",
            Request::StatsFetch { .. } => "stats",
            Request::Traced { inner, .. } => inner.op(),
            Request::MetaBatch { .. } => "specflush",
        }
    }

    /// Metadata op (vs data op)? Used by the §2.1 motivation analyzer.
    pub fn is_metadata(&self) -> bool {
        match self {
            Request::Stamped { inner, .. } => inner.is_metadata(),
            Request::Traced { inner, .. } => inner.is_metadata(),
            _ => !matches!(
                self,
                Request::Read { .. }
                    | Request::Write { .. }
                    | Request::ReadBatch { .. }
                    | Request::WriteBatch { .. }
            ),
        }
    }

    /// Approximate payload size for the bandwidth model.
    pub fn wire_size(&self) -> usize {
        match self {
            Request::Write { data, .. } => 64 + data.len(),
            Request::ResolvePath { components, .. } => {
                64 + components.iter().map(|c| 4 + c.len()).sum::<usize>()
            }
            Request::ReadBatch { ranges, .. } => 64 + ranges.len() * 12,
            Request::WriteBatch { segs, .. } => {
                64 + segs.iter().map(|s| 12 + s.data.len()).sum::<usize>()
            }
            Request::JournalShip { frames } => 64 + frames.len(),
            Request::Stamped { inner, .. } => 24 + inner.wire_size(),
            Request::Traced { inner, .. } => 16 + inner.wire_size(),
            Request::MetaBatch { ops, .. } => 64 + ops.len() * 48,
            Request::SubtreeImport { frames } => 64 + frames.len(),
            _ => 64,
        }
    }
}

impl Response {
    pub fn wire_size(&self) -> usize {
        match self {
            Response::Data { data, .. } => 32 + data.len(),
            Response::Entries { entries, .. } => 64 + entries.len() * 48,
            Response::Opened { inline, .. } => 64 + inline.as_ref().map_or(0, |d| d.len()),
            Response::Walked { dirs, .. } => {
                32 + dirs.iter().map(|d| 64 + d.entries.len() * 48).sum::<usize>()
            }
            Response::DataBatch { segs, .. } => {
                32 + segs.iter().map(|s| 4 + s.len()).sum::<usize>()
            }
            Response::OpenedInline { data, .. } => 64 + data.as_ref().map_or(0, |d| d.len()),
            Response::JournalChunk { frames, .. } => 32 + frames.len(),
            Response::PlacementMap { entries, .. } => 32 + entries.len() * 16,
            Response::Stats { json, spans } => 32 + json.len() + spans.len() * 64,
            Response::Batch(items) => 8 + items.iter().map(|r| r.wire_size()).sum::<usize>(),
            _ => 32,
        }
    }

    /// Unwrap into a result (errors become `Err`).
    pub fn into_result(self) -> FsResult<Response> {
        match self {
            Response::Err(e) => Err(e),
            other => Ok(other),
        }
    }
}

// ---------------------------------------------------------------------------
// Wire impls
// ---------------------------------------------------------------------------

impl Wire for Credentials {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.uid);
        e.u32(self.gid);
        e.u32(self.groups.len() as u32);
        for g in &self.groups {
            e.u32(*g);
        }
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        let uid = d.u32()?;
        let gid = d.u32()?;
        let n = d.u32()? as usize;
        if n > 1024 {
            return Err(FsError::Protocol(format!("too many groups: {n}")));
        }
        let mut groups = Vec::with_capacity(n);
        for _ in 0..n {
            groups.push(d.u32()?);
        }
        Ok(Credentials { uid, gid, groups })
    }
}

impl Wire for OpenCtx {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.client);
        e.u64(self.handle);
        self.flags.enc(e);
        self.cred.enc(e);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(OpenCtx {
            client: d.u32()?,
            handle: d.u64()?,
            flags: OpenFlags::dec(d)?,
            cred: Credentials::dec(d)?,
        })
    }
}

macro_rules! tagged {
    ($e:expr, $tag:expr) => {{
        $e.u8($tag);
    }};
}

impl Wire for LeaseStamp {
    fn enc(&self, e: &mut Enc) {
        self.node.enc(e);
        e.u64(self.epoch);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(LeaseStamp { node: Ino::dec(d)?, epoch: d.u64()? })
    }
}

impl Wire for ByteRange {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.off);
        e.u32(self.len);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(ByteRange { off: d.u64()?, len: d.u32()? })
    }
}

impl Wire for WriteSeg {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.off);
        e.bytes(&self.data);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(WriteSeg { off: d.u64()?, data: d.bytes()? })
    }
}

impl Wire for BatchOp {
    fn enc(&self, e: &mut Enc) {
        match self {
            BatchOp::Create { name, mode, kind } => {
                e.u8(0);
                e.str(name);
                e.u16(*mode);
                kind.enc(e);
            }
            BatchOp::Mkdir { name, mode } => {
                e.u8(1);
                e.str(name);
                e.u16(*mode);
            }
            BatchOp::Unlink { name } => {
                e.u8(2);
                e.str(name);
            }
            BatchOp::Rmdir { name } => {
                e.u8(3);
                e.str(name);
            }
            BatchOp::Rename { sname, dname } => {
                e.u8(4);
                e.str(sname);
                e.str(dname);
            }
            BatchOp::Close { ino, handle } => {
                e.u8(5);
                ino.enc(e);
                e.u64(*handle);
            }
        }
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(match d.u8()? {
            0 => BatchOp::Create { name: d.str()?, mode: d.u16()?, kind: FileKind::dec(d)? },
            1 => BatchOp::Mkdir { name: d.str()?, mode: d.u16()? },
            2 => BatchOp::Unlink { name: d.str()? },
            3 => BatchOp::Rmdir { name: d.str()? },
            4 => BatchOp::Rename { sname: d.str()?, dname: d.str()? },
            5 => BatchOp::Close { ino: Ino::dec(d)?, handle: d.u64()? },
            t => return Err(FsError::Protocol(format!("bad batch op tag {t}"))),
        })
    }
}

impl Wire for BatchItem {
    fn enc(&self, e: &mut Enc) {
        e.u64(self.op_id);
        self.op.enc(e);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(BatchItem { op_id: d.u64()?, op: BatchOp::dec(d)? })
    }
}

impl Wire for Request {
    fn enc(&self, e: &mut Enc) {
        match self {
            Request::Lookup { dir, name, cred } => {
                tagged!(e, 0);
                dir.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::ReadDir { dir, client, register, cred } => {
                tagged!(e, 1);
                dir.enc(e);
                e.u32(*client);
                e.bool(*register);
                cred.enc(e);
            }
            Request::GetAttr { ino } => {
                tagged!(e, 2);
                ino.enc(e);
            }
            Request::Open { ino, flags, cred, client, handle, want_inline } => {
                tagged!(e, 3);
                ino.enc(e);
                flags.enc(e);
                cred.enc(e);
                e.u32(*client);
                e.u64(*handle);
                e.bool(*want_inline);
            }
            Request::Read { ino, off, len, open_ctx } => {
                tagged!(e, 4);
                ino.enc(e);
                e.u64(*off);
                e.u32(*len);
                open_ctx.enc(e);
            }
            Request::Write { ino, off, data, open_ctx } => {
                tagged!(e, 5);
                ino.enc(e);
                e.u64(*off);
                e.bytes(data);
                open_ctx.enc(e);
            }
            Request::Close { ino, client, handle } => {
                tagged!(e, 6);
                ino.enc(e);
                e.u32(*client);
                e.u64(*handle);
            }
            Request::Create { dir, name, mode, kind, cred, client } => {
                tagged!(e, 7);
                dir.enc(e);
                e.str(name);
                e.u16(*mode);
                kind.enc(e);
                cred.enc(e);
                e.u32(*client);
            }
            Request::Mkdir { dir, name, mode, cred } => {
                tagged!(e, 8);
                dir.enc(e);
                e.str(name);
                e.u16(*mode);
                cred.enc(e);
            }
            Request::Unlink { dir, name, cred } => {
                tagged!(e, 9);
                dir.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::Rmdir { dir, name, cred } => {
                tagged!(e, 10);
                dir.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::Rename { sdir, sname, ddir, dname, cred } => {
                tagged!(e, 11);
                sdir.enc(e);
                e.str(sname);
                ddir.enc(e);
                e.str(dname);
                cred.enc(e);
            }
            Request::Chmod { ino, mode, cred } => {
                tagged!(e, 12);
                ino.enc(e);
                e.u16(*mode);
                cred.enc(e);
            }
            Request::Chown { ino, uid, gid, cred } => {
                tagged!(e, 13);
                ino.enc(e);
                e.u32(*uid);
                e.u32(*gid);
                cred.enc(e);
            }
            Request::Truncate { ino, size, cred } => {
                tagged!(e, 14);
                ino.enc(e);
                e.u64(*size);
                cred.enc(e);
            }
            Request::Statfs { host } => {
                tagged!(e, 15);
                e.u16(*host);
            }
            Request::Hello { client } => {
                tagged!(e, 16);
                e.u32(*client);
            }
            Request::PrepareInvalidate { dir } => {
                tagged!(e, 17);
                dir.enc(e);
            }
            Request::UpdateDirentPerm { dir, name, perm } => {
                tagged!(e, 18);
                dir.enc(e);
                e.str(name);
                perm.enc(e);
            }
            Request::CreateOrphan { parent, name, mode, kind, uid, gid } => {
                tagged!(e, 19);
                parent.enc(e);
                e.str(name);
                e.u16(*mode);
                kind.enc(e);
                e.u32(*uid);
                e.u32(*gid);
            }
            Request::DropObject { ino } => {
                tagged!(e, 20);
                ino.enc(e);
            }
            Request::OpenByName { dir, name, flags, cred, client, handle, want_inline } => {
                tagged!(e, 21);
                dir.enc(e);
                e.str(name);
                flags.enc(e);
                cred.enc(e);
                e.u32(*client);
                e.u64(*handle);
                e.bool(*want_inline);
            }
            Request::ResolvePath { base, components, client, register, cred } => {
                tagged!(e, 22);
                base.enc(e);
                components.enc(e);
                e.u32(*client);
                e.bool(*register);
                cred.enc(e);
            }
            Request::Lease { node, client, cred } => {
                tagged!(e, 23);
                node.enc(e);
                e.u32(*client);
                cred.enc(e);
            }
            Request::OpenAt { lease, name, flags, cred, client, handle, want_inline } => {
                tagged!(e, 24);
                lease.enc(e);
                e.str(name);
                flags.enc(e);
                cred.enc(e);
                e.u32(*client);
                e.u64(*handle);
                e.bool(*want_inline);
            }
            Request::StatAt { lease, name, cred } => {
                tagged!(e, 25);
                lease.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::ReadDirAt { lease, client, register, cred } => {
                tagged!(e, 26);
                lease.enc(e);
                e.u32(*client);
                e.bool(*register);
                cred.enc(e);
            }
            Request::CreateAt { lease, name, mode, kind, cred, client } => {
                tagged!(e, 27);
                lease.enc(e);
                e.str(name);
                e.u16(*mode);
                kind.enc(e);
                cred.enc(e);
                e.u32(*client);
            }
            Request::MkdirAt { lease, name, mode, cred } => {
                tagged!(e, 28);
                lease.enc(e);
                e.str(name);
                e.u16(*mode);
                cred.enc(e);
            }
            Request::UnlinkAt { lease, name, cred } => {
                tagged!(e, 29);
                lease.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::RmdirAt { lease, name, cred } => {
                tagged!(e, 30);
                lease.enc(e);
                e.str(name);
                cred.enc(e);
            }
            Request::RenameAt { src, sname, dst, dname, cred } => {
                tagged!(e, 31);
                src.enc(e);
                e.str(sname);
                dst.enc(e);
                e.str(dname);
                cred.enc(e);
            }
            Request::ReadBatch { ino, ranges, known_gen, client, register, open_ctx } => {
                tagged!(e, 32);
                ino.enc(e);
                ranges.enc(e);
                e.u64(*known_gen);
                e.u32(*client);
                e.bool(*register);
                open_ctx.enc(e);
            }
            Request::WriteBatch { ino, segs, base_gen, client, register, open_ctx } => {
                tagged!(e, 33);
                ino.enc(e);
                segs.enc(e);
                e.u64(*base_gen);
                e.u32(*client);
                e.bool(*register);
                open_ctx.enc(e);
            }
            Request::JournalShip { frames } => {
                tagged!(e, 34);
                e.bytes(frames);
            }
            Request::Stamped { client, op_id, ack_upto, inner } => {
                tagged!(e, 35);
                e.u32(*client);
                e.u64(*op_id);
                e.u64(*ack_upto);
                inner.enc(e);
            }
            Request::JournalFetch { gen, offset, max_bytes } => {
                tagged!(e, 36);
                e.u64(*gen);
                e.u64(*offset);
                e.u32(*max_bytes);
            }
            Request::PlacementFetch { since } => {
                tagged!(e, 37);
                e.u64(*since);
            }
            Request::MigrateSubtree { dir, target, grace } => {
                tagged!(e, 38);
                dir.enc(e);
                e.u16(*target);
                e.u32(*grace);
            }
            Request::SubtreeImport { frames } => {
                tagged!(e, 39);
                e.bytes(frames);
            }
            Request::UpdateParentMeta { ino, parent, name } => {
                tagged!(e, 40);
                ino.enc(e);
                parent.enc(e);
                e.str(name);
            }
            Request::StatsFetch { sections, trace_id } => {
                tagged!(e, 41);
                e.u32(*sections);
                e.u64(*trace_id);
            }
            Request::Traced { trace_id, parent_span, inner } => {
                tagged!(e, 42);
                e.u64(*trace_id);
                e.u64(*parent_span);
                inner.enc(e);
            }
            Request::MetaBatch { lease, client, ack_upto, cred, ops } => {
                tagged!(e, 43);
                lease.enc(e);
                e.u32(*client);
                e.u64(*ack_upto);
                cred.enc(e);
                ops.enc(e);
            }
        }
    }

    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(match d.u8()? {
            0 => Request::Lookup { dir: Ino::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            1 => Request::ReadDir {
                dir: Ino::dec(d)?,
                client: d.u32()?,
                register: d.bool()?,
                cred: Credentials::dec(d)?,
            },
            2 => Request::GetAttr { ino: Ino::dec(d)? },
            3 => Request::Open {
                ino: Ino::dec(d)?,
                flags: OpenFlags::dec(d)?,
                cred: Credentials::dec(d)?,
                client: d.u32()?,
                handle: d.u64()?,
                want_inline: d.bool()?,
            },
            4 => Request::Read {
                ino: Ino::dec(d)?,
                off: d.u64()?,
                len: d.u32()?,
                open_ctx: Option::<OpenCtx>::dec(d)?,
            },
            5 => Request::Write {
                ino: Ino::dec(d)?,
                off: d.u64()?,
                data: d.bytes()?,
                open_ctx: Option::<OpenCtx>::dec(d)?,
            },
            6 => Request::Close { ino: Ino::dec(d)?, client: d.u32()?, handle: d.u64()? },
            7 => Request::Create {
                dir: Ino::dec(d)?,
                name: d.str()?,
                mode: d.u16()?,
                kind: FileKind::dec(d)?,
                cred: Credentials::dec(d)?,
                client: d.u32()?,
            },
            8 => Request::Mkdir { dir: Ino::dec(d)?, name: d.str()?, mode: d.u16()?, cred: Credentials::dec(d)? },
            9 => Request::Unlink { dir: Ino::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            10 => Request::Rmdir { dir: Ino::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            11 => Request::Rename {
                sdir: Ino::dec(d)?,
                sname: d.str()?,
                ddir: Ino::dec(d)?,
                dname: d.str()?,
                cred: Credentials::dec(d)?,
            },
            12 => Request::Chmod { ino: Ino::dec(d)?, mode: d.u16()?, cred: Credentials::dec(d)? },
            13 => Request::Chown { ino: Ino::dec(d)?, uid: d.u32()?, gid: d.u32()?, cred: Credentials::dec(d)? },
            14 => Request::Truncate { ino: Ino::dec(d)?, size: d.u64()?, cred: Credentials::dec(d)? },
            15 => Request::Statfs { host: d.u16()? },
            16 => Request::Hello { client: d.u32()? },
            17 => Request::PrepareInvalidate { dir: Ino::dec(d)? },
            18 => Request::UpdateDirentPerm {
                dir: Ino::dec(d)?,
                name: d.str()?,
                perm: crate::types::PermBlob::dec(d)?,
            },
            19 => Request::CreateOrphan {
                parent: Ino::dec(d)?,
                name: d.str()?,
                mode: d.u16()?,
                kind: FileKind::dec(d)?,
                uid: d.u32()?,
                gid: d.u32()?,
            },
            20 => Request::DropObject { ino: Ino::dec(d)? },
            21 => Request::OpenByName {
                dir: Ino::dec(d)?,
                name: d.str()?,
                flags: OpenFlags::dec(d)?,
                cred: Credentials::dec(d)?,
                client: d.u32()?,
                handle: d.u64()?,
                want_inline: d.bool()?,
            },
            22 => Request::ResolvePath {
                base: Ino::dec(d)?,
                components: Vec::<String>::dec(d)?,
                client: d.u32()?,
                register: d.bool()?,
                cred: Credentials::dec(d)?,
            },
            23 => Request::Lease { node: Ino::dec(d)?, client: d.u32()?, cred: Credentials::dec(d)? },
            24 => Request::OpenAt {
                lease: LeaseStamp::dec(d)?,
                name: d.str()?,
                flags: OpenFlags::dec(d)?,
                cred: Credentials::dec(d)?,
                client: d.u32()?,
                handle: d.u64()?,
                want_inline: d.bool()?,
            },
            25 => Request::StatAt { lease: LeaseStamp::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            26 => Request::ReadDirAt {
                lease: LeaseStamp::dec(d)?,
                client: d.u32()?,
                register: d.bool()?,
                cred: Credentials::dec(d)?,
            },
            27 => Request::CreateAt {
                lease: LeaseStamp::dec(d)?,
                name: d.str()?,
                mode: d.u16()?,
                kind: FileKind::dec(d)?,
                cred: Credentials::dec(d)?,
                client: d.u32()?,
            },
            28 => Request::MkdirAt {
                lease: LeaseStamp::dec(d)?,
                name: d.str()?,
                mode: d.u16()?,
                cred: Credentials::dec(d)?,
            },
            29 => Request::UnlinkAt { lease: LeaseStamp::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            30 => Request::RmdirAt { lease: LeaseStamp::dec(d)?, name: d.str()?, cred: Credentials::dec(d)? },
            31 => Request::RenameAt {
                src: LeaseStamp::dec(d)?,
                sname: d.str()?,
                dst: LeaseStamp::dec(d)?,
                dname: d.str()?,
                cred: Credentials::dec(d)?,
            },
            32 => Request::ReadBatch {
                ino: Ino::dec(d)?,
                ranges: Vec::<ByteRange>::dec(d)?,
                known_gen: d.u64()?,
                client: d.u32()?,
                register: d.bool()?,
                open_ctx: Option::<OpenCtx>::dec(d)?,
            },
            33 => Request::WriteBatch {
                ino: Ino::dec(d)?,
                segs: Vec::<WriteSeg>::dec(d)?,
                base_gen: d.u64()?,
                client: d.u32()?,
                register: d.bool()?,
                open_ctx: Option::<OpenCtx>::dec(d)?,
            },
            34 => Request::JournalShip { frames: d.bytes()? },
            35 => Request::Stamped {
                client: d.u32()?,
                op_id: d.u64()?,
                ack_upto: d.u64()?,
                inner: Box::new(Request::dec(d)?),
            },
            36 => Request::JournalFetch {
                gen: d.u64()?,
                offset: d.u64()?,
                max_bytes: d.u32()?,
            },
            37 => Request::PlacementFetch { since: d.u64()? },
            38 => Request::MigrateSubtree { dir: Ino::dec(d)?, target: d.u16()?, grace: d.u32()? },
            39 => Request::SubtreeImport { frames: d.bytes()? },
            40 => Request::UpdateParentMeta {
                ino: Ino::dec(d)?,
                parent: Ino::dec(d)?,
                name: d.str()?,
            },
            41 => Request::StatsFetch { sections: d.u32()?, trace_id: d.u64()? },
            42 => Request::Traced {
                trace_id: d.u64()?,
                parent_span: d.u64()?,
                inner: Box::new(Request::dec(d)?),
            },
            43 => Request::MetaBatch {
                lease: LeaseStamp::dec(d)?,
                client: d.u32()?,
                ack_upto: d.u64()?,
                cred: Credentials::dec(d)?,
                ops: Vec::<BatchItem>::dec(d)?,
            },
            t => return Err(FsError::Protocol(format!("bad request tag {t}"))),
        })
    }
}

impl Wire for Response {
    fn enc(&self, e: &mut Enc) {
        match self {
            Response::Entry(de) => {
                tagged!(e, 0);
                de.enc(e);
            }
            Response::Entries { dir, entries } => {
                tagged!(e, 1);
                dir.enc(e);
                entries.enc(e);
            }
            Response::AttrR(a) => {
                tagged!(e, 2);
                a.enc(e);
            }
            Response::Opened { attr, inline } => {
                tagged!(e, 3);
                attr.enc(e);
                match inline {
                    None => e.u8(0),
                    Some(data) => {
                        e.u8(1);
                        e.bytes(data);
                    }
                }
            }
            Response::Data { data, size } => {
                tagged!(e, 4);
                e.bytes(data);
                e.u64(*size);
            }
            Response::Written { written, new_size } => {
                tagged!(e, 5);
                e.u32(*written);
                e.u64(*new_size);
            }
            Response::Created(de) => {
                tagged!(e, 6);
                de.enc(e);
            }
            Response::Statfs { files, bytes } => {
                tagged!(e, 7);
                e.u64(*files);
                e.u64(*bytes);
            }
            Response::Unit => tagged!(e, 8),
            Response::Err(err) => {
                tagged!(e, 9);
                let (code, msg) = err.to_wire();
                e.u16(code);
                e.str(&msg);
                e.u16(err.wire_aux());
            }
            Response::Walked { dirs, walked, next } => {
                tagged!(e, 10);
                dirs.enc(e);
                e.u32(*walked);
                next.enc(e);
            }
            Response::Leased { attr, epoch } => {
                tagged!(e, 11);
                attr.enc(e);
                e.u64(*epoch);
            }
            Response::DataBatch { segs, size, data_gen } => {
                tagged!(e, 12);
                e.u32(segs.len() as u32);
                for s in segs {
                    e.bytes(s);
                }
                e.u64(*size);
                e.u64(*data_gen);
            }
            Response::WrittenBatch { written, new_size, data_gen } => {
                tagged!(e, 13);
                e.u64(*written);
                e.u64(*new_size);
                e.u64(*data_gen);
            }
            Response::OpenedInline { attr, data_gen, data } => {
                tagged!(e, 14);
                attr.enc(e);
                e.u64(*data_gen);
                match data {
                    None => e.u8(0),
                    Some(d) => {
                        e.u8(1);
                        e.bytes(d);
                    }
                }
            }
            Response::JournalChunk { gen, offset, frames, more } => {
                tagged!(e, 15);
                e.u64(*gen);
                e.u64(*offset);
                e.bytes(frames);
                e.bool(*more);
            }
            Response::PlacementMap { version, entries } => {
                tagged!(e, 16);
                e.u64(*version);
                entries.enc(e);
            }
            Response::Migrated { files, map_version } => {
                tagged!(e, 17);
                e.u64(*files);
                e.u64(*map_version);
            }
            Response::Stats { json, spans } => {
                tagged!(e, 18);
                e.str(json);
                spans.enc(e);
            }
            Response::Batch(items) => {
                tagged!(e, 19);
                e.u32(items.len() as u32);
                for r in items {
                    r.enc(e);
                }
            }
        }
    }

    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(match d.u8()? {
            0 => Response::Entry(DirEntry::dec(d)?),
            1 => Response::Entries { dir: Attr::dec(d)?, entries: Vec::<DirEntry>::dec(d)? },
            2 => Response::AttrR(Attr::dec(d)?),
            3 => {
                let attr = Attr::dec(d)?;
                let inline = match d.u8()? {
                    0 => None,
                    1 => Some(d.bytes()?),
                    t => return Err(FsError::Protocol(format!("bad inline tag {t}"))),
                };
                Response::Opened { attr, inline }
            }
            4 => Response::Data { data: d.bytes()?, size: d.u64()? },
            5 => Response::Written { written: d.u32()?, new_size: d.u64()? },
            6 => Response::Created(DirEntry::dec(d)?),
            7 => Response::Statfs { files: d.u64()?, bytes: d.u64()? },
            8 => Response::Unit,
            9 => {
                let code = d.u16()?;
                let msg = d.str()?;
                let aux = d.u16()?;
                Response::Err(FsError::from_wire(code, msg, aux))
            }
            10 => Response::Walked {
                dirs: Vec::<WalkedDir>::dec(d)?,
                walked: d.u32()?,
                next: Option::<Ino>::dec(d)?,
            },
            11 => Response::Leased { attr: Attr::dec(d)?, epoch: d.u64()? },
            12 => {
                let n = d.u32()? as usize;
                if n > 65536 {
                    return Err(FsError::Protocol(format!("oversized batch: {n}")));
                }
                let mut segs = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    segs.push(d.bytes()?);
                }
                Response::DataBatch { segs, size: d.u64()?, data_gen: d.u64()? }
            }
            13 => Response::WrittenBatch {
                written: d.u64()?,
                new_size: d.u64()?,
                data_gen: d.u64()?,
            },
            14 => {
                let attr = Attr::dec(d)?;
                let data_gen = d.u64()?;
                let data = match d.u8()? {
                    0 => None,
                    1 => Some(d.bytes()?),
                    t => return Err(FsError::Protocol(format!("bad inline tag {t}"))),
                };
                Response::OpenedInline { attr, data_gen, data }
            }
            15 => Response::JournalChunk {
                gen: d.u64()?,
                offset: d.u64()?,
                frames: d.bytes()?,
                more: d.bool()?,
            },
            16 => Response::PlacementMap {
                version: d.u64()?,
                entries: Vec::<PlacementEntry>::dec(d)?,
            },
            17 => Response::Migrated { files: d.u64()?, map_version: d.u64()? },
            18 => Response::Stats {
                json: d.str()?,
                spans: Vec::<crate::obs::Span>::dec(d)?,
            },
            19 => {
                let n = d.u32()? as usize;
                if n > 65536 {
                    return Err(FsError::Protocol(format!("oversized batch: {n}")));
                }
                let mut items = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    items.push(Response::dec(d)?);
                }
                Response::Batch(items)
            }
            t => return Err(FsError::Protocol(format!("bad response tag {t}"))),
        })
    }
}

impl Wire for WalkedDir {
    fn enc(&self, e: &mut Enc) {
        self.attr.enc(e);
        self.entries.enc(e);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(WalkedDir { attr: Attr::dec(d)?, entries: Vec::<DirEntry>::dec(d)? })
    }
}

impl Wire for PlacementEntry {
    fn enc(&self, e: &mut Enc) {
        self.dir.enc(e);
        e.u16(self.owner);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(PlacementEntry { dir: Ino::dec(d)?, owner: d.u16()? })
    }
}

impl Wire for Notify {
    fn enc(&self, e: &mut Enc) {
        match self {
            Notify::Invalidate { seq, dirs } => {
                e.u8(0);
                e.u64(*seq);
                dirs.enc(e);
            }
            Notify::DataInvalidate { seq, ino, gen } => {
                e.u8(1);
                e.u64(*seq);
                ino.enc(e);
                e.u64(*gen);
            }
        }
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(match d.u8()? {
            0 => Notify::Invalidate { seq: d.u64()?, dirs: Vec::<Ino>::dec(d)? },
            1 => Notify::DataInvalidate { seq: d.u64()?, ino: Ino::dec(d)?, gen: d.u64()? },
            t => return Err(FsError::Protocol(format!("bad notify tag {t}"))),
        })
    }
}

impl Wire for NotifyAck {
    fn enc(&self, e: &mut Enc) {
        e.u32(self.client);
        e.u64(self.seq);
    }
    fn dec(d: &mut Dec) -> FsResult<Self> {
        Ok(NotifyAck { client: d.u32()?, seq: d.u64()? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::PermBlob;
    use crate::util::rng::XorShift;

    fn cred() -> Credentials {
        Credentials::with_groups(1000, 1000, vec![4, 24])
    }

    fn sample_requests() -> Vec<Request> {
        let ino = Ino::new(1, 0, 42);
        let ctx = OpenCtx { client: 3, handle: 7, flags: OpenFlags::RDWR, cred: cred() };
        vec![
            Request::Lookup { dir: ino, name: "a".into(), cred: cred() },
            Request::ReadDir { dir: ino, client: 3, register: true, cred: cred() },
            Request::GetAttr { ino },
            Request::Open { ino, flags: OpenFlags::RDONLY, cred: cred(), client: 3, handle: 9, want_inline: true },
            Request::Read { ino, off: 4096, len: 4096, open_ctx: Some(ctx.clone()) },
            Request::Write { ino, off: 0, data: vec![9; 100], open_ctx: None },
            Request::Close { ino, client: 3, handle: 7 },
            Request::Create { dir: ino, name: "f".into(), mode: 0o644, kind: FileKind::Regular, cred: cred(), client: 3 },
            Request::Mkdir { dir: ino, name: "d".into(), mode: 0o755, cred: cred() },
            Request::Unlink { dir: ino, name: "f".into(), cred: cred() },
            Request::Rmdir { dir: ino, name: "d".into(), cred: cred() },
            Request::Rename { sdir: ino, sname: "x".into(), ddir: ino, dname: "y".into(), cred: cred() },
            Request::Chmod { ino, mode: 0o600, cred: cred() },
            Request::Chown { ino, uid: 1, gid: 2, cred: cred() },
            Request::Truncate { ino, size: 0, cred: cred() },
            Request::Statfs { host: 2 },
            Request::Hello { client: 5 },
            Request::PrepareInvalidate { dir: ino },
            Request::UpdateDirentPerm { dir: ino, name: "f".into(), perm: PermBlob::new(0o600, 1, 2) },
            Request::CreateOrphan { parent: ino, name: "o".into(), mode: 0o644, kind: FileKind::Regular, uid: 1, gid: 2 },
            Request::DropObject { ino },
            Request::OpenByName { dir: ino, name: "f".into(), flags: OpenFlags::RDONLY, cred: cred(), client: 1, handle: 2, want_inline: true },
            Request::ResolvePath {
                base: ino,
                components: vec!["a".into(), "b".into(), "f.dat".into()],
                client: 3,
                register: true,
                cred: cred(),
            },
            Request::ResolvePath { base: ino, components: vec![], client: 3, register: false, cred: cred() },
            Request::Lease { node: ino, client: 3, cred: cred() },
            Request::OpenAt {
                lease: LeaseStamp { node: ino, epoch: 4 },
                name: "f".into(),
                flags: OpenFlags::RDONLY,
                cred: cred(),
                client: 3,
                handle: 11,
                want_inline: true,
            },
            Request::StatAt {
                lease: LeaseStamp { node: ino, epoch: 0 },
                name: "f".into(),
                cred: cred(),
            },
            Request::ReadDirAt {
                lease: LeaseStamp { node: ino, epoch: 9 },
                client: 3,
                register: true,
                cred: cred(),
            },
            Request::CreateAt {
                lease: LeaseStamp { node: ino, epoch: 1 },
                name: "n".into(),
                mode: 0o644,
                kind: FileKind::Regular,
                cred: cred(),
                client: 3,
            },
            Request::MkdirAt {
                lease: LeaseStamp { node: ino, epoch: 2 },
                name: "d".into(),
                mode: 0o755,
                cred: cred(),
            },
            Request::UnlinkAt {
                lease: LeaseStamp { node: ino, epoch: 3 },
                name: "f".into(),
                cred: cred(),
            },
            Request::RmdirAt {
                lease: LeaseStamp { node: ino, epoch: 3 },
                name: "d".into(),
                cred: cred(),
            },
            Request::RenameAt {
                src: LeaseStamp { node: ino, epoch: 5 },
                sname: "x".into(),
                dst: LeaseStamp { node: Ino::new(1, 0, 7), epoch: 6 },
                dname: "y".into(),
                cred: cred(),
            },
            Request::ReadBatch {
                ino,
                ranges: vec![ByteRange { off: 0, len: 4096 }, ByteRange { off: 8192, len: 8192 }],
                known_gen: 3,
                client: 3,
                register: true,
                open_ctx: Some(ctx.clone()),
            },
            Request::ReadBatch {
                ino,
                ranges: vec![],
                known_gen: NO_GEN,
                client: 3,
                register: false,
                open_ctx: None,
            },
            Request::WriteBatch {
                ino,
                segs: vec![
                    WriteSeg { off: 100, data: vec![1; 300] },
                    WriteSeg { off: 9000, data: vec![2; 10] },
                ],
                base_gen: NO_GEN,
                client: 3,
                register: true,
                open_ctx: Some(ctx.clone()),
            },
            Request::JournalShip { frames: vec![0xde, 0xad, 0xbe, 0xef] },
            Request::Stamped {
                client: 7,
                op_id: 42,
                ack_upto: 40,
                inner: Box::new(Request::Chmod { ino, mode: 0o600, cred: cred() }),
            },
            Request::JournalFetch { gen: 3, offset: 4096, max_bytes: 1 << 20 },
            Request::PlacementFetch { since: 12 },
            Request::MigrateSubtree { dir: ino, target: 2, grace: 64 },
            Request::SubtreeImport { frames: vec![0xca, 0xfe] },
            Request::UpdateParentMeta {
                ino,
                parent: Ino::new(1, 0, 7),
                name: "moved".into(),
            },
            Request::StatsFetch { sections: crate::obs::SEC_ALL, trace_id: 0 },
            Request::StatsFetch { sections: crate::obs::SEC_SPANS, trace_id: 0xdead_beef },
            Request::Traced {
                trace_id: 77,
                parent_span: 3,
                inner: Box::new(Request::GetAttr { ino }),
            },
            Request::Traced {
                trace_id: 78,
                parent_span: 0,
                inner: Box::new(Request::Stamped {
                    client: 7,
                    op_id: 43,
                    ack_upto: 41,
                    inner: Box::new(Request::Chmod { ino, mode: 0o600, cred: cred() }),
                }),
            },
            Request::MetaBatch {
                lease: LeaseStamp { node: ino, epoch: 3 },
                client: 3,
                ack_upto: 40,
                cred: cred(),
                ops: vec![
                    BatchItem {
                        op_id: 41,
                        op: BatchOp::Create { name: "f".into(), mode: 0o644, kind: FileKind::Regular },
                    },
                    BatchItem { op_id: 42, op: BatchOp::Mkdir { name: "d".into(), mode: 0o755 } },
                    BatchItem { op_id: 43, op: BatchOp::Unlink { name: "old".into() } },
                    BatchItem { op_id: 44, op: BatchOp::Rmdir { name: "gone".into() } },
                    BatchItem {
                        op_id: 45,
                        op: BatchOp::Rename { sname: "x".into(), dname: "y".into() },
                    },
                    BatchItem { op_id: 46, op: BatchOp::Close { ino, handle: 9 } },
                ],
            },
            Request::MetaBatch {
                lease: LeaseStamp { node: ino, epoch: 0 },
                client: 3,
                ack_upto: 0,
                cred: cred(),
                ops: vec![],
            },
        ]
    }

    fn sample_responses() -> Vec<Response> {
        let attr = Attr {
            ino: Ino::new(1, 0, 42),
            kind: FileKind::Regular,
            perm: PermBlob::new(0o644, 1, 2),
            size: 4096,
            nlink: 1,
            atime: 1,
            mtime: 2,
            ctime: 3,
        };
        let de = DirEntry {
            name: "foo".into(),
            ino: attr.ino,
            kind: FileKind::Regular,
            perm: attr.perm,
        };
        vec![
            Response::Entry(de.clone()),
            Response::Entries { dir: attr.clone(), entries: vec![de.clone(), de.clone()] },
            Response::AttrR(attr.clone()),
            Response::Opened { attr: attr.clone(), inline: Some(vec![1, 2, 3]) },
            Response::Opened { attr: attr.clone(), inline: None },
            Response::Data { data: vec![0; 4096], size: 4096 },
            Response::Written { written: 100, new_size: 100 },
            Response::Created(de.clone()),
            Response::Statfs { files: 10, bytes: 40960 },
            Response::Unit,
            Response::Err(FsError::PermissionDenied),
            Response::Err(FsError::NoSuchServer(3)),
            Response::Walked {
                dirs: vec![
                    WalkedDir { attr: attr.clone(), entries: vec![de.clone(), de.clone()] },
                    WalkedDir { attr: attr.clone(), entries: vec![] },
                ],
                walked: 2,
                next: Some(Ino::new(2, 0, 9)),
            },
            Response::Walked { dirs: vec![], walked: 0, next: None },
            Response::Leased { attr: attr.clone(), epoch: 42 },
            Response::Err(FsError::StaleLease),
            Response::DataBatch {
                segs: vec![vec![1; 4096], vec![], vec![9; 10]],
                size: 8202,
                data_gen: 7,
            },
            Response::DataBatch { segs: vec![], size: 0, data_gen: 0 },
            Response::WrittenBatch { written: 310, new_size: 9010, data_gen: 8 },
            Response::OpenedInline { attr: attr.clone(), data_gen: 3, data: Some(vec![5; 100]) },
            Response::OpenedInline { attr: attr.clone(), data_gen: 0, data: None },
            Response::Err(FsError::StaleData),
            Response::JournalChunk {
                gen: 2,
                offset: 8192,
                frames: vec![0xaa, 0xbb, 0xcc],
                more: true,
            },
            Response::JournalChunk { gen: 0, offset: 0, frames: vec![], more: false },
            Response::Err(FsError::JournalFailed("disk gone".into())),
            Response::PlacementMap {
                version: 3,
                entries: vec![
                    PlacementEntry { dir: Ino::new(0, 0, 5), owner: 1 },
                    PlacementEntry { dir: Ino::new(1, 0, 9), owner: 0 },
                ],
            },
            Response::PlacementMap { version: 0, entries: vec![] },
            Response::Migrated { files: 40, map_version: 4 },
            Response::Err(FsError::WrongServer { owner: 2, map_version: 7 }),
            Response::Stats { json: "{\"ops\":{}}".into(), spans: vec![] },
            Response::Stats {
                json: String::new(),
                spans: vec![crate::obs::Span {
                    trace_id: 77,
                    span_id: 5,
                    parent: 3,
                    name: "getattr".into(),
                    note: "wrong_server->2".into(),
                    host: 1,
                    server: true,
                    start_us: 1000,
                    dur_us: 120,
                }],
            },
            Response::Batch(vec![
                Response::Created(de.clone()),
                Response::Unit,
                Response::Err(FsError::AlreadyExists),
            ]),
            Response::Batch(vec![]),
        ]
    }

    #[test]
    fn request_roundtrip() {
        for r in sample_requests() {
            let back = Request::from_bytes(&r.to_bytes()).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn response_roundtrip() {
        for r in sample_responses() {
            let back = Response::from_bytes(&r.to_bytes()).unwrap();
            assert_eq!(back, r);
        }
    }

    #[test]
    fn notify_roundtrip() {
        let n = Notify::Invalidate { seq: 9, dirs: vec![Ino::new(1, 0, 2), Ino::new(2, 1, 3)] };
        assert_eq!(Notify::from_bytes(&n.to_bytes()).unwrap(), n);
        let n = Notify::DataInvalidate { seq: 10, ino: Ino::new(1, 0, 2), gen: 5 };
        assert_eq!(Notify::from_bytes(&n.to_bytes()).unwrap(), n);
        let a = NotifyAck { client: 4, seq: 9 };
        assert_eq!(NotifyAck::from_bytes(&a.to_bytes()).unwrap(), a);
    }

    #[test]
    fn data_ops_classify_as_data_rpcs() {
        let ino = Ino::new(0, 0, 1);
        let rb = Request::ReadBatch {
            ino,
            ranges: vec![ByteRange { off: 0, len: 4096 }],
            known_gen: NO_GEN,
            client: 1,
            register: true,
            open_ctx: None,
        };
        let wb = Request::WriteBatch {
            ino,
            segs: vec![WriteSeg { off: 0, data: vec![0; 64] }],
            base_gen: NO_GEN,
            client: 1,
            register: true,
            open_ctx: None,
        };
        assert_eq!(rb.op(), "read");
        assert_eq!(wb.op(), "write");
        assert!(!rb.is_metadata());
        assert!(!wb.is_metadata());
        assert!(wb.wire_size() >= 64 + 64, "batch payload counts toward bandwidth");
    }

    #[test]
    fn every_request_truncation_fails_cleanly() {
        for r in sample_requests() {
            let bytes = r.to_bytes();
            for cut in 0..bytes.len() {
                assert!(Request::from_bytes(&bytes[..cut]).is_err());
            }
        }
    }

    #[test]
    fn op_names_and_metadata_classification() {
        for r in sample_requests() {
            assert!(!r.op().is_empty());
        }
        assert!(Request::GetAttr { ino: Ino::new(0, 0, 0) }.is_metadata());
        assert!(!Request::Read { ino: Ino::new(0, 0, 0), off: 0, len: 1, open_ctx: None }.is_metadata());
    }

    #[test]
    fn fuzz_decode_never_panics() {
        let mut r = XorShift::new(0xfeed);
        for _ in 0..5000 {
            let n = r.below(200) as usize;
            let garbage: Vec<u8> = (0..n).map(|_| r.next_u64() as u8).collect();
            let _ = Request::from_bytes(&garbage);
            let _ = Response::from_bytes(&garbage);
            let _ = Notify::from_bytes(&garbage);
        }
    }
}
