//! Workload generation: file sets, access streams, and the §2.1
//! motivation-trace analyzer.
//!
//! File-set construction bypasses the simulated network and the service
//! capacity model entirely (direct server calls) — the paper regenerates
//! its 100 000-file set before every test and does not measure setup.

pub mod motivation;


use crate::baseline::{LustreCluster, LustreMode, MdsServer};
use crate::cluster::BuffetCluster;
use crate::error::FsResult;
use crate::transport::Service;
use crate::types::{Credentials, FileKind, Ino};
use crate::util::rng::XorShift;
use crate::wire::{Request, Response};

/// The Fig. 4 file population: `n_files` files of `file_size` bytes spread
/// over `n_dirs` directories ("file quantity: 100,000, file size: 4KB").
#[derive(Clone, Copy, Debug)]
pub struct FileSetSpec {
    pub n_files: usize,
    pub n_dirs: usize,
    pub file_size: u32,
    /// Owner of the generated files (processes run with this uid/gid).
    pub uid: u32,
    pub gid: u32,
}

impl FileSetSpec {
    pub fn paper_scale() -> FileSetSpec {
        FileSetSpec { n_files: 100_000, n_dirs: 100, file_size: 4096, uid: 1000, gid: 1000 }
    }

    pub fn scaled(self, factor: usize) -> FileSetSpec {
        FileSetSpec {
            n_files: (self.n_files / factor.max(1)).max(self.n_dirs),
            ..self
        }
    }

    pub fn dir_name(&self, i: usize) -> String {
        format!("d{:03}", i % self.n_dirs)
    }

    pub fn dir_path(&self, i: usize) -> String {
        format!("/{}", self.dir_name(i))
    }

    /// Path of file `i` (files round-robin over directories).
    pub fn path(&self, i: usize) -> String {
        format!("/{}/f{:06}.dat", self.dir_name(i), i)
    }
}

/// Build the file set on a BuffetFS cluster via direct (unmetered)
/// server calls. Returns the per-file payload used.
pub fn build_fileset_buffet(cluster: &BuffetCluster, spec: &FileSetSpec) -> FsResult<Vec<u8>> {
    let cred = Credentials::root();
    let root = cluster.root();
    let s0 = &cluster.servers[0];
    let payload = vec![0xabu8; spec.file_size as usize];
    let mut dirs: Vec<Ino> = Vec::with_capacity(spec.n_dirs);
    for d in 0..spec.n_dirs {
        let resp = s0.handle(Request::Mkdir {
            dir: root,
            name: spec.dir_name(d),
            mode: 0o755,
            cred: cred.clone(),
        });
        match resp {
            Response::Created(e) => {
                // hand the directory to the workload user so its
                // processes can populate and later write files
                s0.fs.chown_apply(e.ino.file, spec.uid, spec.gid)?;
                dirs.push(e.ino);
            }
            other => return Err(unexpected(other)),
        }
    }
    for i in 0..spec.n_files {
        let dir = dirs[i % spec.n_dirs];
        let resp = s0.handle(Request::Create {
            dir,
            name: format!("f{i:06}.dat"),
            mode: 0o644,
            kind: FileKind::Regular,
            cred: Credentials::with_groups(spec.uid, spec.gid, vec![]),
            client: 0,
        });
        let ino = match resp {
            Response::Created(e) => e.ino,
            other => return Err(unexpected(other)),
        };
        // data may live on another server in spread mode
        let owner = &cluster.servers[ino.host as usize];
        match owner.handle(Request::Write { ino, off: 0, data: payload.clone(), open_ctx: None }) {
            Response::Written { .. } => {}
            other => return Err(unexpected(other)),
        }
    }
    Ok(payload)
}

/// Same for a Lustre cluster: namespace on the MDS, data on the
/// layout-selected OSS (Normal) or the MDS itself (DoM).
pub fn build_fileset_lustre(cluster: &LustreCluster, spec: &FileSetSpec) -> FsResult<Vec<u8>> {
    let cred = Credentials::root();
    let root = cluster.mds.fs.root_ino();
    let payload = vec![0xabu8; spec.file_size as usize];
    let mut dirs: Vec<Ino> = Vec::with_capacity(spec.n_dirs);
    for d in 0..spec.n_dirs {
        match cluster.mds.handle(Request::Mkdir {
            dir: root,
            name: spec.dir_name(d),
            mode: 0o755,
            cred: cred.clone(),
        }) {
            Response::Created(e) => {
                cluster.mds.fs.chown_apply(e.ino.file, spec.uid, spec.gid)?;
                dirs.push(e.ino);
            }
            other => return Err(unexpected(other)),
        }
    }
    let dom = matches!(cluster.mode, LustreMode::Dom { .. });
    for i in 0..spec.n_files {
        let dir = dirs[i % spec.n_dirs];
        let ino = match cluster.mds.handle(Request::Create {
            dir,
            name: format!("f{i:06}.dat"),
            mode: 0o644,
            kind: FileKind::Regular,
            cred: Credentials::with_groups(spec.uid, spec.gid, vec![]),
            client: 0,
        }) {
            Response::Created(e) => e.ino,
            other => return Err(unexpected(other)),
        };
        if dom {
            // DoM: small-file data resides on the MDS
            match cluster.mds.handle(Request::Write { ino, off: 0, data: payload.clone(), open_ctx: None }) {
                Response::Written { .. } => {}
                other => return Err(unexpected(other)),
            }
        } else {
            let host = MdsServer::oss_for(cluster.osses.len() as u16, ino.file);
            let oss = &cluster.osses[(host - 1) as usize];
            match oss.handle(Request::Write {
                ino: Ino::new(host, 0, ino.file),
                off: 0,
                data: payload.clone(),
                open_ctx: None,
            }) {
                Response::Written { .. } => {}
                other => return Err(unexpected(other)),
            }
            // keep the MDS's size metadata honest (Lustre gets this via
            // OSS glimpse; we shortcut at setup time)
            let file = ino.file;
            cluster.mds.fs.force_size(file, spec.file_size as u64);
        }
    }
    Ok(payload)
}

fn unexpected(r: Response) -> crate::error::FsError {
    crate::error::FsError::Protocol(format!("fileset setup: unexpected {r:?}"))
}

/// Random access stream over a file set ("randomly accesses 1000 files
/// among 100000"). `zipf_s = 0` is the paper's uniform choice.
pub struct AccessStream {
    rng: XorShift,
    n_files: usize,
    zipf_s: f64,
}

impl AccessStream {
    pub fn new(seed: u64, n_files: usize, zipf_s: f64) -> AccessStream {
        AccessStream { rng: XorShift::new(seed), n_files, zipf_s }
    }

    pub fn next_index(&mut self) -> usize {
        if self.zipf_s > 0.0 {
            self.rng.zipf(self.n_files as u64, self.zipf_s) as usize
        } else {
            self.rng.below(self.n_files as u64) as usize
        }
    }
}

/// Worker credential for generated workloads (owner of the file set).
pub fn workload_cred(spec: &FileSetSpec) -> Credentials {
    Credentials::with_groups(spec.uid, spec.gid, vec![])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Backing;
    use crate::simnet::NetConfig;
    use crate::transport::capacity::ServiceConfig;

    fn tiny_spec() -> FileSetSpec {
        FileSetSpec { n_files: 50, n_dirs: 5, file_size: 256, uid: 1000, gid: 1000 }
    }

    #[test]
    fn paths_are_stable_and_partitioned() {
        let s = tiny_spec();
        assert_eq!(s.path(0), "/d000/f000000.dat");
        assert_eq!(s.path(7), "/d002/f000007.dat");
        assert_eq!(s.dir_path(7), "/d002");
    }

    #[test]
    fn buffet_fileset_readable_by_owner() {
        let cluster = BuffetCluster::spawn_with(
            1,
            NetConfig::zero(),
            Backing::Mem,
            false,
            ServiceConfig::unbounded(),
        );
        let spec = tiny_spec();
        let payload = build_fileset_buffet(&cluster, &spec).unwrap();
        let (agent, metrics) = cluster.make_agent();
        let p = crate::blib::Buffet::process(agent, workload_cred(&spec));
        let data = p.get(&spec.path(13), spec.file_size).unwrap();
        assert_eq!(data, payload);
        // one readdir (dir fetch) + one read; open cost zero RPCs
        assert_eq!(metrics.count("open"), 0);
        assert_eq!(metrics.count("read"), 1);
    }

    #[test]
    fn lustre_fileset_readable_both_modes() {
        for mode in [LustreMode::Normal, LustreMode::dom_default()] {
            let cluster = LustreCluster::spawn_with(
                4,
                mode,
                NetConfig::zero(),
                Backing::Mem,
                ServiceConfig::unbounded(),
            );
            let spec = tiny_spec();
            let payload = build_fileset_lustre(&cluster, &spec).unwrap();
            let (client, metrics) = cluster.make_client();
            let cred = workload_cred(&spec);
            let data = client.get(1, &spec.path(3), spec.file_size, &cred).unwrap();
            assert_eq!(data, payload, "mode {mode:?}");
            assert_eq!(metrics.count("open"), 1, "Lustre must RPC the open");
            if mode == LustreMode::Normal {
                assert_eq!(metrics.count("read"), 1);
            } else {
                assert_eq!(metrics.count("read"), 0, "DoM read must be served inline");
            }
        }
    }

    #[test]
    fn access_stream_uniform_covers_range() {
        let mut s = AccessStream::new(7, 100, 0.0);
        let mut seen = vec![false; 100];
        for _ in 0..5000 {
            let i = s.next_index();
            assert!(i < 100);
            seen[i] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() > 90);
    }
}
