//! §2.1 motivation statistics, regenerated.
//!
//! The paper reports two aggregates from a TaihuLight Lustre OSS serving
//! machine-learning jobs and the Beacon monitor:
//!   * "more than 90% RPCs come from accessing small files", and
//!   * "more than 70% of metadata operations are open() and close()".
//!
//! We regenerate them from a parameterized synthetic trace: a mixture of
//! small-file accesses (whole-file, open-read/write-close) and large-file
//! accesses (many sequential 1 MiB transfers per open), played against
//! the Lustre RPC schedule (open RPC + one data RPC per MiB + close RPC
//! + a lookup share for cold dentries). Mixture defaults are calibrated
//! to the quoted shares and documented in EXPERIMENTS.md.

use crate::util::rng::XorShift;

#[derive(Clone, Copy, Debug)]
pub struct TraceMix {
    /// Fraction of file accesses that hit small files.
    pub small_access_fraction: f64,
    /// Small file size (bytes) — one data RPC.
    pub small_size: u64,
    /// Large file size (bytes) — `size / chunk` data RPCs.
    pub large_size: u64,
    /// Data RPC transfer chunk (Lustre RPC size, 1 MiB default).
    pub chunk: u64,
    /// Probability a path component misses the dentry cache (adds a
    /// lookup RPC — a metadata op that is *not* open/close).
    pub lookup_miss: f64,
    /// Fraction of accesses that also stat() first.
    pub stat_fraction: f64,
}

impl Default for TraceMix {
    fn default() -> Self {
        // ML + monitoring mix: overwhelmingly small files (§2.1), warm
        // dentry caches, occasional stat
        TraceMix {
            small_access_fraction: 0.995,
            small_size: 64 << 10,
            large_size: 32 << 20,
            chunk: 1 << 20,
            lookup_miss: 0.05,
            stat_fraction: 0.10,
        }
    }
}

#[derive(Debug, Default, Clone, Copy)]
pub struct TraceStats {
    pub total_rpcs: u64,
    pub rpcs_from_small: u64,
    pub metadata_rpcs: u64,
    pub open_close_rpcs: u64,
    pub data_rpcs: u64,
}

impl TraceStats {
    /// "more than 90% RPCs come from accessing small files"
    pub fn small_rpc_share(&self) -> f64 {
        self.rpcs_from_small as f64 / self.total_rpcs.max(1) as f64
    }

    /// "more than 70% of metadata operations are open() and close()"
    pub fn open_close_meta_share(&self) -> f64 {
        self.open_close_rpcs as f64 / self.metadata_rpcs.max(1) as f64
    }
}

/// Play `n_accesses` file accesses through the Lustre RPC schedule and
/// count where RPCs come from.
pub fn simulate(mix: &TraceMix, n_accesses: u64, seed: u64) -> TraceStats {
    let mut rng = XorShift::new(seed);
    let mut st = TraceStats::default();
    for _ in 0..n_accesses {
        let small = rng.f64() < mix.small_access_fraction;
        let size = if small { mix.small_size } else { mix.large_size };
        let mut rpcs = 0u64;
        let mut meta = 0u64;
        let mut oc = 0u64;

        // path walk: D=3 components, each may miss the dentry cache
        for _ in 0..3 {
            if rng.f64() < mix.lookup_miss {
                rpcs += 1;
                meta += 1;
            }
        }
        if rng.f64() < mix.stat_fraction {
            rpcs += 1;
            meta += 1;
        }
        // open + close (close async but still an RPC the server serves)
        rpcs += 2;
        meta += 2;
        oc += 2;
        // data transfers
        let data = size.div_ceil(mix.chunk);
        rpcs += data;

        st.total_rpcs += rpcs;
        st.metadata_rpcs += meta;
        st.open_close_rpcs += oc;
        st.data_rpcs += data;
        if small {
            st.rpcs_from_small += rpcs;
        }
    }
    st
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_mix_reproduces_paper_shares() {
        let st = simulate(&TraceMix::default(), 200_000, 42);
        let small = st.small_rpc_share();
        let oc = st.open_close_meta_share();
        assert!(small > 0.90, "small-file RPC share {small:.3} ≤ 0.90");
        assert!(oc > 0.70, "open/close metadata share {oc:.3} ≤ 0.70");
    }

    #[test]
    fn large_file_mix_flips_the_story() {
        // mostly large files → data RPCs dominate, small share collapses
        let mix = TraceMix { small_access_fraction: 0.10, ..TraceMix::default() };
        let st = simulate(&mix, 50_000, 42);
        assert!(st.small_rpc_share() < 0.2);
    }

    #[test]
    fn deterministic_by_seed() {
        let a = simulate(&TraceMix::default(), 10_000, 7);
        let b = simulate(&TraceMix::default(), 10_000, 7);
        assert_eq!(a.total_rpcs, b.total_rpcs);
        assert_eq!(a.rpcs_from_small, b.rpcs_from_small);
    }
}
