//! Concurrency: many client processes hammering the stack at once —
//! server-side lock correctness, deferred-open idempotency under racing
//! first-reads, and capacity-model sanity.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};

fn cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        2,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 5 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

#[test]
fn concurrent_writers_never_tear_whole_file_writes() {
    let c = cluster();
    let (agent, _) = c.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.put("/hot", &[0u8; 512]).unwrap();

    // 8 writers each rewrite the whole file with their own byte; the
    // server's exclusive write lock must keep every snapshot uniform
    std::thread::scope(|scope| {
        for w in 0..8u8 {
            let agent = agent.clone();
            scope.spawn(move || {
                let p = Buffet::process(agent, Credentials::root());
                for _ in 0..50 {
                    let fd = p.open("/hot", OpenFlags::WRONLY).unwrap();
                    p.pwrite(fd, 0, &[w + 1; 512]).unwrap();
                    p.close(fd).unwrap();
                }
            });
        }
        let agent = agent.clone();
        scope.spawn(move || {
            let p = Buffet::process(agent, Credentials::root());
            for _ in 0..200 {
                let data = p.get("/hot", 512).unwrap();
                assert!(!data.is_empty());
                let first = data[0];
                assert!(
                    data.iter().all(|&b| b == first),
                    "torn read: saw mixed bytes {:?}…",
                    &data[..8]
                );
            }
        });
    });
}

#[test]
fn racing_first_reads_complete_open_exactly_once() {
    let c = cluster();
    let (agent, _) = c.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/race", &[1u8; 64]).unwrap();
    p.get("/race", 1).unwrap(); // warm
    let file = p.stat("/race").unwrap().ino.file;

    let fd = p.open("/race", OpenFlags::RDONLY).unwrap();
    let pid = p.pid();
    // many threads race pread on the SAME incomplete fd
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let agent = agent.clone();
            scope.spawn(move || {
                for _ in 0..20 {
                    agent.pread(pid, fd, 0, 8).unwrap();
                }
            });
        }
    });
    assert_eq!(
        c.servers[0].openers_of(file),
        1,
        "deferred open must be recorded exactly once per handle"
    );
    p.close(fd).unwrap();
}

#[test]
fn many_processes_many_files_all_data_correct() {
    let c = cluster();
    let (agent, _) = c.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/farm", 0o777).unwrap();
    let total = Arc::new(AtomicU64::new(0));
    std::thread::scope(|scope| {
        for w in 0..16 {
            let agent = agent.clone();
            let total = Arc::clone(&total);
            scope.spawn(move || {
                let p = Buffet::process(agent, Credentials::new(1000 + w, 1000));
                for i in 0..25 {
                    let path = format!("/farm/w{w}-{i}");
                    let body = format!("{w}:{i}");
                    p.put(&path, body.as_bytes()).unwrap();
                    let back = p.get(&path, 64).unwrap();
                    assert_eq!(back, body.as_bytes());
                    total.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(total.load(Ordering::Relaxed), 16 * 25);
    assert_eq!(admin.readdir("/farm").unwrap().len(), 16 * 25);
}

#[test]
fn bounded_capacity_under_load_still_correct() {
    // 1 service slot: heavy queueing, but every byte still lands
    let c = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig { slots: 1, meta_us: 50, data_us: 50, data_us_per_4k: 0 },
    );
    let (agent, _) = c.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/q", 0o777).unwrap();
    std::thread::scope(|scope| {
        for w in 0..6 {
            let agent = agent.clone();
            scope.spawn(move || {
                let p = Buffet::process(agent, Credentials::root());
                for i in 0..10 {
                    p.put(&format!("/q/{w}-{i}"), &[w as u8; 128]).unwrap();
                }
            });
        }
    });
    assert_eq!(admin.readdir("/q").unwrap().len(), 60);
}

#[test]
fn async_closes_drain_under_churn() {
    let c = cluster();
    let (agent, _) = c.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.put("/churn", &[1u8; 32]).unwrap();
    let file = p.stat("/churn").unwrap().ino.file;
    for _ in 0..100 {
        let fd = p.open("/churn", OpenFlags::RDONLY).unwrap();
        p.read(fd, 4).unwrap();
        p.close(fd).unwrap();
    }
    for _ in 0..200 {
        if c.servers[0].openers_of(file) == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("async close backlog never drained: {} open", c.servers[0].openers_of(file));
}
