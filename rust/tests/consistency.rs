//! The §3.4 strong-consistency protocol, end to end: invalidate every
//! caching client, collect acks, only then apply — so no client can ever
//! act on stale permission bits.

use std::sync::atomic::Ordering;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};

fn cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 3 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

#[test]
fn chmod_invalidates_other_clients_before_applying() {
    let c = cluster();
    let (agent_a, _) = c.make_agent();
    let (agent_b, _) = c.make_agent();
    let admin = Buffet::process(agent_a.clone(), Credentials::root());
    admin.mkdir("/shared", 0o755).unwrap();
    admin.put("/shared/f", b"payload!").unwrap();
    admin.chmod("/shared/f", 0o644).unwrap();

    // B warms its cache and can read
    let b = Buffet::process(agent_b.clone(), Credentials::new(500, 500));
    assert_eq!(b.get("/shared/f", 8).unwrap(), b"payload!");
    assert_eq!(c.servers[0].clients_caching(b.stat("/shared/f").unwrap().ino.file), Vec::<u32>::new());

    // A revokes world-read; the server must have pushed an invalidation
    // to B (and A) before the chmod returned
    admin.chmod("/shared/f", 0o600).unwrap();
    assert!(agent_b.stats.invalidations_rx.load(Ordering::Relaxed) >= 1);

    // B's very next open re-fetches and is denied — no staleness window
    assert_eq!(b.open("/shared/f", OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);

    // and in the grant direction too: loosening propagates
    admin.chmod("/shared/f", 0o444).unwrap();
    assert_eq!(b.get("/shared/f", 8).unwrap(), b"payload!");
}

#[test]
fn barrier_covers_all_caching_clients() {
    let c = cluster();
    let (admin_agent, _) = c.make_agent();
    let admin = Buffet::process(admin_agent, Credentials::root());
    admin.mkdir("/pop", 0o755).unwrap();
    admin.put("/pop/f", b"x").unwrap();

    let agents: Vec<_> = (0..8).map(|_| c.make_agent().0).collect();
    for a in &agents {
        let p = Buffet::process(a.clone(), Credentials::new(1, 1));
        p.stat("/pop/f").unwrap(); // warms + registers
    }
    let pushed_before = c.servers[0].stats.invalidations_pushed.load(Ordering::Relaxed);
    admin.chmod("/pop/f", 0o640).unwrap();
    let pushed = c.servers[0].stats.invalidations_pushed.load(Ordering::Relaxed) - pushed_before;
    assert!(pushed >= 8, "expected ≥8 invalidation pushes, saw {pushed}");
    for a in &agents {
        assert!(a.stats.invalidations_rx.load(Ordering::Relaxed) >= 1);
    }
}

#[test]
fn namespace_mutations_invalidate_too() {
    // §3.4: "other metadata modifications, such as changing file name …
    // need to ask the related clients to invalidate"
    let c = cluster();
    let (agent_a, _) = c.make_agent();
    let (agent_b, metrics_b) = c.make_agent();
    let a = Buffet::process(agent_a, Credentials::root());
    a.mkdir("/ns", 0o755).unwrap();
    a.put("/ns/old", b"v").unwrap();

    let b = Buffet::process(agent_b.clone(), Credentials::root());
    b.get("/ns/old", 1).unwrap(); // B caches /ns

    a.rename("/ns/old", "/ns/new").unwrap();
    // B's cached listing was invalidated; next access refetches and sees
    // the new name (no stale ENOENT from the cache)
    let before = metrics_b.total_rpcs();
    assert_eq!(b.get("/ns/new", 1).unwrap(), b"v");
    assert!(metrics_b.total_rpcs() > before, "B must refetch after rename invalidation");
    assert_eq!(b.open("/ns/old", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);

    // unlink through A likewise invalidates B
    let rx_before = agent_b.stats.invalidations_rx.load(Ordering::Relaxed);
    a.unlink("/ns/new").unwrap();
    assert!(agent_b.stats.invalidations_rx.load(Ordering::Relaxed) > rx_before);
    assert_eq!(b.open("/ns/new", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);
}

#[test]
fn chown_propagates_ownership_to_cached_blobs() {
    let c = cluster();
    let (agent_a, _) = c.make_agent();
    let (agent_b, _) = c.make_agent();
    let admin = Buffet::process(agent_a, Credentials::root());
    admin.mkdir("/own", 0o755).unwrap();
    admin.put("/own/f", b"z").unwrap();
    admin.chmod("/own/f", 0o640).unwrap(); // owner rw, group r

    let b = Buffet::process(agent_b, Credentials::new(800, 800));
    assert_eq!(b.open("/own/f", OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);
    // give the file to uid 800
    admin.chown("/own/f", 800, 800).unwrap();
    assert_eq!(b.get("/own/f", 1).unwrap(), b"z");
    // B's local blob now carries the new owner — a *write* open is local-checked too
    let fd = b.open("/own/f", OpenFlags::RDWR).unwrap();
    b.close(fd).unwrap();
}

#[test]
fn self_inflicted_invalidation_keeps_own_cache_coherent() {
    // the chmod-issuing client also caches the dir; the barrier must not
    // deadlock on it and its own next check must see the new bits
    let c = cluster();
    let (agent, _) = c.make_agent();
    let owner = Buffet::process(agent.clone(), Credentials::new(100, 100));
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/self", 0o777).unwrap();
    owner.put("/self/mine", b"m").unwrap();
    owner.get("/self/mine", 1).unwrap();

    owner.chmod("/self/mine", 0o000).unwrap(); // revoke even own read
    assert_eq!(owner.open("/self/mine", OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);
    owner.chmod("/self/mine", 0o600).unwrap();
    assert_eq!(owner.get("/self/mine", 1).unwrap(), b"m");
}
