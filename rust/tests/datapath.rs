//! The client data plane end-to-end (DESIGN.md §7), acceptance criteria:
//!
//! * open + full read of a ≤ inline-limit file costs **0 data RPCs**;
//! * a sequential 1 MiB scan costs ≤ ⌈size / read-ahead-window⌉ read RPCs;
//! * 100 small `write()`s followed by `close()` flush in ≤ 2 RPCs;
//! * a remote writer bumping the data generation causes exactly one
//!   drop-and-retry with no stale bytes returned;
//! * `RpcMetrics` reports the page-cache / read-ahead / flush-coalescing
//!   counters `BENCH_datapath.json` consumes.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::datapath::DatapathConfig;
use buffetfs::metrics::RpcMetrics;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::Service;
use buffetfs::types::{Credentials, OpenFlags};
use buffetfs::wire::Request;

fn fast_cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 11 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

fn pattern(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i * 37 % 253) as u8).collect()
}

/// Data RPCs = read + write ops (ReadBatch/WriteBatch count as such).
fn data_rpcs(m: &Arc<RpcMetrics>) -> u64 {
    m.count("read") + m.count("write")
}

/// Wait for asynchronous close wrap-ups to drain before snapshotting
/// RPC totals.
fn quiesce(metrics: &RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(std::time::Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

#[test]
fn inline_open_full_read_costs_zero_data_rpcs() {
    let cluster = fast_cluster();
    let (setup, _) = cluster.make_agent();
    let admin = Buffet::process(setup, Credentials::root());
    admin.mkdir("/d", 0o755).unwrap();
    let content = pattern(2048);
    admin.put("/d/small.txt", &content).unwrap();

    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let p = Buffet::process(agent.clone(), Credentials::new(1000, 1000));
    let before = data_rpcs(&metrics);
    let fd = p.open("/d/small.txt", OpenFlags::RDONLY).unwrap();
    let got = p.read(fd, 1 << 16).unwrap();
    assert_eq!(got, content);
    assert!(p.read(fd, 4096).unwrap().is_empty(), "EOF");
    p.close(fd).unwrap();
    assert_eq!(
        data_rpcs(&metrics) - before,
        0,
        "open + full read of a small file must issue zero data RPCs"
    );
    assert_eq!(metrics.inline_opens(), 1, "the contents rode the one open RPC");

    // a second open+read of the same file is served entirely locally:
    // zero RPCs of ANY kind (warm dir cache + warm page cache)
    quiesce(&metrics);
    let total_before = metrics.total_rpcs();
    let fd = p.open("/d/small.txt", OpenFlags::RDONLY).unwrap();
    assert_eq!(p.read(fd, 1 << 16).unwrap(), content);
    p.close(fd).unwrap();
    assert_eq!(metrics.total_rpcs(), total_before, "fully cached access is RPC-free");
    assert!(metrics.page_hits() > 0);
}

#[test]
fn sequential_scan_pays_one_rpc_per_readahead_window() {
    let cluster = fast_cluster();
    let (setup, _) = cluster.make_agent();
    let admin = Buffet::process(setup, Credentials::root());
    admin.mkdir("/d", 0o755).unwrap();
    let size = 1 << 20;
    let content = pattern(size);
    admin.put("/d/big.bin", &content).unwrap();

    let (agent, metrics) = cluster.make_agent();
    let cfg = DatapathConfig::default();
    agent.enable_datapath(cfg);
    let p = Buffet::process(agent, Credentials::new(1000, 1000));
    let fd = p.open("/d/big.bin", OpenFlags::RDONLY).unwrap();
    let mut got = Vec::with_capacity(size);
    loop {
        let chunk = p.read(fd, 4096).unwrap();
        if chunk.is_empty() {
            break;
        }
        got.extend_from_slice(&chunk);
    }
    p.close(fd).unwrap();
    assert_eq!(got, content);
    let budget = (size as u64).div_ceil(cfg.readahead_window as u64);
    assert!(
        metrics.count("read") <= budget,
        "1 MiB scan took {} read RPCs, budget is ceil(size/window) = {}",
        metrics.count("read"),
        budget
    );
    assert_eq!(metrics.count("write"), 0);
    assert!(metrics.readahead_pages() > 0, "read-ahead must have prefetched");
    assert!(metrics.page_hits() > 0, "most 4 KiB reads are page-cache hits");
}

#[test]
fn hundred_writes_then_close_flush_in_at_most_two_rpcs() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.mkdir("/w", 0o755).unwrap();
    let fd = p.open("/w/out.log", OpenFlags::RDWR.with_create()).unwrap();
    let before = data_rpcs(&metrics);
    for i in 0..100u64 {
        assert_eq!(p.write(fd, &[i as u8; 100]).unwrap(), 100);
    }
    // read-your-writes straight from the buffer
    let back = p.pread(fd, 150, 100).unwrap();
    assert_eq!(&back[..50], &[1u8; 50][..]);
    assert_eq!(&back[50..], &[2u8; 50][..]);
    assert_eq!(data_rpcs(&metrics) - before, 0, "writes are buffered client-side");
    p.close(fd).unwrap();
    let flushed = data_rpcs(&metrics) - before;
    assert!(flushed <= 2, "100 writes + close flushed in {flushed} data RPCs, want <= 2");
    assert_eq!(metrics.wb_writes(), 100);
    assert!(metrics.wb_flush_rpcs() >= 1);
    assert_eq!(metrics.wb_flush_segs(), 1, "sequential writes coalesced into one extent");

    // durability: a vanilla (no-datapath) client sees every byte
    let (plain, _) = cluster.make_agent();
    let q = Buffet::process(plain, Credentials::root());
    let fd = q.open("/w/out.log", OpenFlags::RDONLY).unwrap();
    let got = q.read(fd, 20_000).unwrap();
    assert_eq!(got.len(), 10_000);
    for i in 0..100usize {
        assert!(got[i * 100..(i + 1) * 100].iter().all(|&b| b == i as u8), "chunk {i}");
    }
    q.close(fd).unwrap();
}

#[test]
fn explicit_fsync_flushes_once_and_close_flushes_the_rest() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let p = Buffet::process(agent, Credentials::root());
    let fd = p.open("/sync.dat", OpenFlags::RDWR.with_create()).unwrap();
    p.write(fd, &[1; 512]).unwrap();
    p.write(fd, &[2; 512]).unwrap();
    p.fsync(fd).unwrap();
    assert_eq!(metrics.count("write"), 1, "fsync coalesced two writes into one flush");
    p.fsync(fd).unwrap();
    assert_eq!(metrics.count("write"), 1, "fsync with nothing dirty is free");
    p.write(fd, &[3; 512]).unwrap();
    p.close(fd).unwrap();
    assert_eq!(metrics.count("write"), 2, "close flushed the remainder");
}

#[test]
fn remote_writer_causes_exactly_one_drop_and_retry_no_stale_bytes() {
    let cluster = fast_cluster();
    let (setup, _) = cluster.make_agent();
    let admin = Buffet::process(setup, Credentials::root());
    admin.mkdir("/d", 0o755).unwrap();
    let size = 64 << 10;
    admin.put("/d/shared", &pattern(size)).unwrap();
    let ino = admin.stat("/d/shared").unwrap().ino;

    // reader: no inline, no read-ahead, and — crucially for this test —
    // no push registration, so staleness is caught by the generation
    // stamp on the next fetch, not by an invalidation push
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig {
        inline_limit: 0,
        readahead_window: 0,
        register_data: false,
        ..DatapathConfig::default()
    });
    let p = Buffet::process(agent.clone(), Credentials::new(1000, 1000));
    let fd = p.open("/d/shared", OpenFlags::RDONLY).unwrap();
    // cache the first two pages under the current generation
    assert_eq!(p.pread(fd, 0, 8192).unwrap(), &pattern(size)[..8192]);

    // a remote writer replaces the whole file behind our back
    let newc: Vec<u8> = (0..size).map(|i| (i % 11) as u8 ^ 0xa5).collect();
    cluster.servers[0].handle(Request::Write {
        ino,
        off: 0,
        data: newc.clone(),
        open_ctx: None,
    });

    // reading uncached pages sends the stale stamp -> StaleData ->
    // drop every page -> one retry -> fresh bytes
    assert_eq!(p.pread(fd, 8192, 8192).unwrap(), &newc[8192..16384]);
    assert_eq!(metrics.stale_data_retries(), 1, "exactly one drop-and-retry");
    // the previously cached prefix was dropped with everything else:
    // no stale byte survives
    assert_eq!(p.pread(fd, 0, 8192).unwrap(), &newc[..8192]);
    assert_eq!(metrics.stale_data_retries(), 1, "no second retry needed");
    p.close(fd).unwrap();
}

#[test]
fn push_invalidation_keeps_two_caching_clients_coherent() {
    let cluster = fast_cluster();
    let (setup, _) = cluster.make_agent();
    let admin = Buffet::process(setup, Credentials::root());
    admin.mkdir("/d", 0o777).unwrap();
    admin.put("/d/shared", &pattern(4096)).unwrap();
    admin.chmod("/d/shared", 0o666).unwrap();

    let (a1, m1) = cluster.make_agent();
    a1.enable_datapath(DatapathConfig::default());
    let reader = Buffet::process(a1.clone(), Credentials::new(1000, 1000));
    let rfd = reader.open("/d/shared", OpenFlags::RDONLY).unwrap();
    assert_eq!(reader.read(rfd, 8192).unwrap(), pattern(4096));

    let (a2, _) = cluster.make_agent();
    a2.enable_datapath(DatapathConfig::default());
    let writer = Buffet::process(a2, Credentials::new(1000, 1000));
    let wfd = writer.open("/d/shared", OpenFlags::WRONLY).unwrap();
    writer.pwrite(wfd, 0, &[0xEE; 64]).unwrap();
    writer.fsync(wfd).unwrap(); // WriteBatch -> server pushes DataInvalidate to a1

    assert!(
        a1.stats.data_invalidations_rx.load(Ordering::Relaxed) >= 1,
        "the reader must have received a data-invalidation push"
    );
    let fresh = reader.pread(rfd, 0, 64).unwrap();
    assert_eq!(fresh, vec![0xEE; 64], "post-push read returns the new bytes");
    assert_eq!(
        m1.stale_data_retries(),
        0,
        "the push (not a StaleData bounce) kept the reader coherent"
    );
    reader.close(rfd).unwrap();
    writer.close(wfd).unwrap();
}

#[test]
fn o_direct_bypasses_the_data_plane() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/direct.dat", &pattern(8192)).unwrap();
    let fd = p.open("/direct.dat", OpenFlags::RDONLY.with_direct()).unwrap();
    let before = metrics.count("read");
    assert_eq!(p.pread(fd, 0, 4096).unwrap(), &pattern(8192)[..4096]);
    assert_eq!(p.pread(fd, 0, 4096).unwrap(), &pattern(8192)[..4096]);
    p.close(fd).unwrap();
    assert_eq!(
        metrics.count("read") - before,
        2,
        "O_DIRECT reads are one synchronous RPC each, never cached"
    );
}

#[test]
fn ftruncate_drops_cache_and_bounds_reads() {
    let cluster = fast_cluster();
    let (agent, _) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/t.dat", &pattern(8192)).unwrap();
    let fd = p.open("/t.dat", OpenFlags::RDWR).unwrap();
    assert_eq!(p.read(fd, 8192).unwrap(), pattern(8192));
    agent.ftruncate(p.pid(), fd, 100).unwrap();
    let got = p.pread(fd, 0, 8192).unwrap();
    assert_eq!(got, &pattern(8192)[..100], "reads are bounded by the truncated size");
    assert!(p.pread(fd, 100, 10).unwrap().is_empty());
    p.close(fd).unwrap();
}

#[test]
fn write_through_mode_stays_coherent_without_buffering() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig { writeback: false, ..DatapathConfig::default() });
    let p = Buffet::process(agent, Credentials::root());
    let fd = p.open("/wt.dat", OpenFlags::RDWR.with_create()).unwrap();
    for i in 0..10u8 {
        p.write(fd, &[i; 100]).unwrap();
    }
    assert_eq!(metrics.count("write"), 10, "write-through pays one RPC per write");
    // reads observe every write (the pages were invalidated, refetched)
    let got = p.pread(fd, 0, 1000).unwrap();
    for i in 0..10usize {
        assert!(got[i * 100..(i + 1) * 100].iter().all(|&b| b == i as u8));
    }
    p.close(fd).unwrap();
}
