//! Failure injection: the §3.2 version mechanism (server reboot →
//! ESTALE), client teardown, and protocol edge cases.

use std::sync::Arc;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster, ClusterView};
use buffetfs::error::FsError;
use buffetfs::metrics::RpcMetrics;
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::ChanTransport;
use buffetfs::types::{Credentials, Ino, OpenFlags};

#[test]
fn server_restart_bumps_version_and_old_inos_go_stale() {
    // v0 incarnation
    let s_v0 = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let t_v0 = ChanTransport::new(s_v0.clone(), net.clone(), metrics.clone());

    let mut view = ClusterView::new(s_v0.fs.root_ino());
    view.add(0, 0, t_v0);
    let agent = buffetfs::agent::BAgent::new(1, view, metrics.clone());
    let p = Buffet::with_pid(agent, 1, Credentials::root());
    p.put("/precious", b"v0 data").unwrap();
    let ino_v0 = p.stat("/precious").unwrap().ino;
    assert_eq!(ino_v0.version, 0);

    // "reboot": same host id, new incarnation (version 1)
    let s_v1 = BServer::new(LocalFs::new(0, 1, Box::new(MemData::new())));
    // a client still holding v0 inos and a v0 host map must see Stale,
    // never wrong data
    let err = s_v1
        .fs
        .validate(ino_v0)
        .expect_err("v0 ino against v1 server must fail");
    assert_eq!(err, FsError::Stale);

    // and a v0-configured ClusterView refuses v1 inos symmetrically
    let mut view_v0 = ClusterView::new(Ino::new(0, 0, 1));
    let t_v1 = ChanTransport::new(s_v1.clone(), net, metrics);
    view_v0.add(0, 0, t_v1);
    let ino_v1 = Ino::new(0, 1, 5);
    match view_v0.transport(ino_v1) {
        Err(FsError::Stale) => {}
        Err(other) => panic!("expected Stale, got {other:?}"),
        Ok(_) => panic!("expected Stale, got a transport"),
    }
}

#[test]
fn client_teardown_cleans_server_state() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let id = agent.id();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/f", b"x").unwrap();
    // leave an open dangling and a cache registration behind
    let fd = p.open("/f", OpenFlags::RDONLY).unwrap();
    p.read(fd, 1).unwrap();
    let file = p.stat("/f").unwrap().ino.file;
    assert!(cluster.servers[0].openers_of(file) >= 1);

    // client crash: the server reaps everything it owned
    cluster.servers[0].drop_client(id);
    assert_eq!(cluster.servers[0].openers_of(file), 0);
    assert!(cluster.servers[0].clients_caching(1).is_empty());
}

#[test]
fn name_too_long_rejected_end_to_end() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    let long = format!("/{}", "x".repeat(300));
    assert_eq!(p.create(&long, 0o644).unwrap_err(), FsError::NameTooLong);
}

#[test]
fn unknown_host_in_inode_fails_cleanly() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    // hand-crafted ino pointing at a host that does not exist
    match agent.cluster().transport(Ino::new(42, 0, 7)) {
        Err(FsError::NoSuchServer(42)) => {}
        Err(other) => panic!("expected NoSuchServer, got {other:?}"),
        Ok(_) => panic!("expected NoSuchServer, got a transport"),
    }
}

#[test]
fn deep_paths_resolve_and_check_correctly() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    // 24 components — deeper than the AOT kernel's D=16, exercising the
    // native fallback in resolve/check
    let mut path = String::new();
    for i in 0..24 {
        path.push_str(&format!("/d{i}"));
        p.mkdir(&path, 0o755).unwrap();
    }
    path.push_str("/leaf");
    p.put(&path, b"deep").unwrap();
    assert_eq!(p.get(&path, 16).unwrap(), b"deep");
    // an X-less component midway blocks the whole walk
    p.chmod("/d0/d1/d2", 0o600).unwrap();
    let user_cluster = p.agent().clone();
    let user = Buffet::process(user_cluster, Credentials::new(5, 5));
    assert_eq!(user.open(&path, OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);
}
