//! Failure injection: the §3.2 version mechanism (server reboot →
//! ESTALE), client teardown, protocol edge cases, and the crash-safety
//! suite (kill-the-primary-mid-storm, torn journal tails, double
//! replay — DESIGN.md §10).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use buffetfs::agent::BAgent;
use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster, ClusterView};
use buffetfs::error::FsError;
use buffetfs::metrics::RpcMetrics;
use buffetfs::server::journal::JournalConfig;
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::ChanTransport;
use buffetfs::transport::faulty::{FaultConfig, FaultyTransport};
use buffetfs::transport::{Service, SharedTransport};
use buffetfs::types::{Credentials, HostId, Ino, OpenFlags, Version};
use buffetfs::util::rng::XorShift;
use buffetfs::wire::{Request, Response};

#[test]
fn server_restart_bumps_version_and_old_inos_go_stale() {
    // v0 incarnation
    let s_v0 = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let t_v0 = ChanTransport::new(s_v0.clone(), net.clone(), metrics.clone());

    let view = ClusterView::new(s_v0.fs.root_ino());
    view.add(0, 0, t_v0);
    let agent = buffetfs::agent::BAgent::new(1, view, metrics.clone());
    let p = Buffet::with_pid(agent, 1, Credentials::root());
    p.put("/precious", b"v0 data").unwrap();
    let ino_v0 = p.stat("/precious").unwrap().ino;
    assert_eq!(ino_v0.version, 0);

    // "reboot": same host id, new incarnation (version 1)
    let s_v1 = BServer::new(LocalFs::new(0, 1, Box::new(MemData::new())));
    // a client still holding v0 inos and a v0 host map must see Stale,
    // never wrong data
    let err = s_v1
        .fs
        .validate(ino_v0)
        .expect_err("v0 ino against v1 server must fail");
    assert_eq!(err, FsError::Stale);

    // and a v0-configured ClusterView refuses v1 inos symmetrically
    let view_v0 = ClusterView::new(Ino::new(0, 0, 1));
    let t_v1 = ChanTransport::new(s_v1.clone(), net, metrics);
    view_v0.add(0, 0, t_v1);
    let ino_v1 = Ino::new(0, 1, 5);
    match view_v0.transport(ino_v1) {
        Err(FsError::Stale) => {}
        Err(other) => panic!("expected Stale, got {other:?}"),
        Ok(_) => panic!("expected Stale, got a transport"),
    }
}

#[test]
fn client_teardown_cleans_server_state() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let id = agent.id();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/f", b"x").unwrap();
    // leave an open dangling and a cache registration behind
    let fd = p.open("/f", OpenFlags::RDONLY).unwrap();
    p.read(fd, 1).unwrap();
    let file = p.stat("/f").unwrap().ino.file;
    assert!(cluster.servers[0].openers_of(file) >= 1);

    // client crash: the server reaps everything it owned
    cluster.servers[0].drop_client(id);
    assert_eq!(cluster.servers[0].openers_of(file), 0);
    assert!(cluster.servers[0].clients_caching(1).is_empty());
}

#[test]
fn name_too_long_rejected_end_to_end() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    let long = format!("/{}", "x".repeat(300));
    assert_eq!(p.create(&long, 0o644).unwrap_err(), FsError::NameTooLong);
}

#[test]
fn unknown_host_in_inode_fails_cleanly() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    // hand-crafted ino pointing at a host that does not exist
    match agent.cluster().transport(Ino::new(42, 0, 7)) {
        Err(FsError::NoSuchServer(42)) => {}
        Err(other) => panic!("expected NoSuchServer, got {other:?}"),
        Ok(_) => panic!("expected NoSuchServer, got a transport"),
    }
}

#[test]
fn deep_paths_resolve_and_check_correctly() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    // 24 components — deeper than the AOT kernel's D=16, exercising the
    // native fallback in resolve/check
    let mut path = String::new();
    for i in 0..24 {
        path.push_str(&format!("/d{i}"));
        p.mkdir(&path, 0o755).unwrap();
    }
    path.push_str("/leaf");
    p.put(&path, b"deep").unwrap();
    assert_eq!(p.get(&path, 16).unwrap(), b"deep");
    // an X-less component midway blocks the whole walk
    p.chmod("/d0/d1/d2", 0o600).unwrap();
    let user_cluster = p.agent().clone();
    let user = Buffet::process(user_cluster, Credentials::new(5, 5));
    assert_eq!(user.open(&path, OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);
}

// ---------------------------------------------------------------------------
// Crash safety: kill the primary mid-storm (DESIGN.md §10). The invariant
// under test is the journal's contract: no acknowledged op is ever lost —
// whether the state comes back via recovery replay or a promoted backup.
// ---------------------------------------------------------------------------

/// Unique scratch directory per test invocation; the journal inside it
/// is the only thing that survives a simulated crash.
fn tdir(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "buffetfs-crash-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn journal_cfg() -> JournalConfig {
    // No fsync in tests (tmpfs + same-process recovery makes it pure
    // overhead); the commit/replay logic under test is identical.
    JournalConfig { sync_data: false, ..JournalConfig::default() }
}

/// A process-scoped client wired straight to `s` over a zero-latency chan.
fn client_for(s: &Arc<BServer>, metrics: Arc<RpcMetrics>) -> Buffet {
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let view = ClusterView::new(s.fs.root_ino());
    view.add(0, 0, ChanTransport::new(s.clone(), net, metrics.clone()));
    Buffet::process(BAgent::new(7, view, metrics), Credentials::root())
}

/// Hard-drop wrapper: after `countdown` admitted requests the "machine"
/// dies — every later request (and the one that spent the last credit)
/// answers a transport error, exactly what a severed connection
/// surfaces. Requests admitted before the drop complete fully: a real
/// crash also lets racing replies escape, and the invariant is about
/// *acknowledged* ops, not in-flight ones.
struct KillSwitch {
    inner: Arc<BServer>,
    countdown: AtomicU64,
    dead: AtomicBool,
}

impl KillSwitch {
    fn arm(inner: Arc<BServer>, after: u64) -> Arc<KillSwitch> {
        Arc::new(KillSwitch { inner, countdown: AtomicU64::new(after), dead: AtomicBool::new(false) })
    }
}

impl Service for KillSwitch {
    fn handle(&self, req: Request) -> Response {
        if self.dead.load(Ordering::Acquire) {
            return Response::Err(FsError::Transport("primary crashed".into()));
        }
        let prev = self.countdown.fetch_sub(1, Ordering::AcqRel);
        if prev <= 1 {
            self.dead.store(true, Ordering::Release);
            return Response::Err(FsError::Transport("primary crashed".into()));
        }
        self.inner.handle(req)
    }
}

/// 8 writer threads hammering `put` through one shared agent. Returns
/// every (path, payload) whose put was *acknowledged* plus the error
/// count. `stop_on_error` models workers that give up once the primary
/// is gone (no standby); with it off, the storm keeps going and its
/// tail lands on whatever the failover path promoted.
fn mutation_storm(agent: &Arc<BAgent>, stop_on_error: bool) -> (Vec<(String, Vec<u8>)>, u64) {
    let acked = Mutex::new(Vec::new());
    let errors = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for w in 0..8u32 {
            let agent = agent.clone();
            let acked = &acked;
            let errors = &errors;
            scope.spawn(move || {
                let p = Buffet::with_pid(agent, 100 + w, Credentials::root());
                let mut mine = Vec::new();
                for i in 0..48u32 {
                    let path = format!("/w{w}-f{i}");
                    let body = format!("payload {w}/{i}").into_bytes();
                    match p.put(&path, &body) {
                        Ok(()) => mine.push((path, body)),
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                            if stop_on_error {
                                break;
                            }
                        }
                    }
                }
                acked.lock().unwrap().extend(mine);
            });
        }
    });
    (acked.into_inner().unwrap(), errors.load(Ordering::Relaxed))
}

#[test]
fn kill_primary_mid_storm_recovery_replay_loses_no_acked_op() {
    let dir = tdir("replay");
    let (acked, errors);
    {
        let s = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
        let mut rng = XorShift::new(0xC0FFEE);
        let kill = KillSwitch::arm(s.clone(), 150 + rng.below(150));
        let metrics = Arc::new(RpcMetrics::new());
        let net = Arc::new(LatencyModel::new(NetConfig::zero()));
        let view = ClusterView::new(s.fs.root_ino());
        view.add(0, 0, ChanTransport::new(kill, net, metrics.clone()));
        let agent = BAgent::new(1, view, metrics);
        let storm = mutation_storm(&agent, true);
        acked = storm.0;
        errors = storm.1;
        // the primary dies here, in-memory state and all: only the
        // journal directory outlives this scope
    }
    assert!(errors > 0, "the kill switch must fire mid-storm");
    assert!(!acked.is_empty(), "some ops must be acked before the crash");

    // a fresh incarnation recovers from the journal alone — the object
    // store starts empty, everything must come back through replay
    let s2 = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    let p = client_for(&s2, Arc::new(RpcMetrics::new()));
    for (path, body) in &acked {
        let got = p
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("acked {path} lost in replay: {e:?}"));
        assert_eq!(&got, body, "{path} came back with different bytes after replay");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_primary_mid_storm_backup_promotion_loses_no_acked_op() {
    let pdir = tdir("prim");
    let bdir = tdir("back");
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    // warm standby serving the SAME host id and version: every ino and
    // lease a client holds stays valid across promotion
    let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    backup.enable_backup_role();
    primary.set_backup(ChanTransport::new(backup.clone(), net.clone(), Arc::new(RpcMetrics::new())));

    let mut rng = XorShift::new(0xFA11);
    let kill = KillSwitch::arm(primary.clone(), 150 + rng.below(150));
    let metrics = Arc::new(RpcMetrics::new());
    let view = ClusterView::new(primary.fs.root_ino());
    view.add(0, 0, ChanTransport::new(kill, net.clone(), metrics.clone()));
    view.register_standby(0, 0, ChanTransport::new(backup.clone(), net, metrics.clone()));
    let agent = BAgent::new(1, view, metrics.clone());

    // workers do NOT stop on the first error: the first transport
    // failure drives the promotion and the storm's tail lands on the
    // backup
    let (acked, errors) = mutation_storm(&agent, false);
    assert!(errors > 0, "the kill switch must fire mid-storm");
    assert!(metrics.failovers() >= 1, "the dead primary must have been failed over");

    // every acked op — acked by the primary (shipped past the backup
    // before the reply) or acked by the promoted backup — is present
    let p = Buffet::with_pid(agent.clone(), 999, Credentials::root());
    for (path, body) in &acked {
        let got = p
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("acked {path} lost across failover: {e:?}"));
        assert_eq!(&got, body, "{path} came back with different bytes after failover");
    }
    // and the promoted backup keeps taking new mutations
    p.put("/after-failover", b"served by the standby").unwrap();
    assert_eq!(p.get("/after-failover", 64).unwrap(), b"served by the standby");
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
}

#[test]
fn checkpoint_compaction_under_storm_loses_no_acked_op() {
    // Regression: a checkpoint used to snapshot without quiescing
    // appends, so an op whose state landed after the snapshot traversal
    // could still slip its record into the doomed segment — the swap
    // deleted the only copy of an acked op. A tiny checkpoint_every
    // forces many compactions while 8 writers hammer the journal.
    let dir = tdir("ckpt");
    let acked;
    {
        let cfg = JournalConfig { checkpoint_every: 48, ..journal_cfg() };
        let s = BServer::recover(0, 0, Box::new(MemData::new()), &dir, cfg).unwrap();
        let metrics = Arc::new(RpcMetrics::new());
        let net = Arc::new(LatencyModel::new(NetConfig::zero()));
        let view = ClusterView::new(s.fs.root_ino());
        view.add(0, 0, ChanTransport::new(s.clone(), net, metrics.clone()));
        let agent = BAgent::new(1, view, metrics);
        let (a, errors) = mutation_storm(&agent, true);
        assert_eq!(errors, 0, "no kill switch armed: the storm must run clean");
        acked = a;
        let ckpts = s
            .fs
            .journal()
            .unwrap()
            .stats()
            .checkpoints
            .load(Ordering::Relaxed);
        assert!(ckpts >= 2, "the storm must drive repeated compactions, got {ckpts}");
    }
    // recovery sees only the post-compaction segment (+ its tail): every
    // acked op must still come back
    let s2 = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    let p = client_for(&s2, Arc::new(RpcMetrics::new()));
    for (path, body) in &acked {
        let got = p
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("acked {path} lost across checkpoints: {e:?}"));
        assert_eq!(&got, body, "{path} came back with different bytes after compaction");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn shipped_frames_are_journaled_once_byte_identical() {
    // Regression: the backup's replay used to route through the public
    // mutation API, journaling every shipped record a second time
    // (re-encoded) next to the `append_raw` copy — and unlink replay
    // emitted an extra DropObject. The backup's journal must be a
    // byte-identical copy of the primary's stream, nothing more.
    let pdir = tdir("ship-p");
    let bdir = tdir("ship-b");
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    backup.enable_backup_role();
    primary.set_backup(ChanTransport::new(backup.clone(), net, Arc::new(RpcMetrics::new())));

    let p = client_for(&primary, Arc::new(RpcMetrics::new()));
    for i in 0..16u32 {
        p.put(&format!("/f{i}"), format!("body {i}").as_bytes()).unwrap();
    }
    // the record kinds whose replay used to double-journal
    p.chmod("/f0", 0o600).unwrap();
    p.rename("/f1", "/g1").unwrap();
    p.unlink("/f2").unwrap();

    let pj = std::fs::read(pdir.join("wal.0.log")).unwrap();
    let bj = std::fs::read(bdir.join("wal.0.log")).unwrap();
    assert_eq!(pj, bj, "backup journal must be byte-identical to the shipped stream");
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
}

#[test]
fn backup_compacts_its_own_journal_on_the_ship_path() {
    // Regression: the ship handler never ran the checkpoint policy, so a
    // long-lived standby's journal grew without bound. The backup runs a
    // tight checkpoint_every while the primary's stays at the default —
    // compaction observed on the backup can only have come from the ship
    // path.
    let pdir = tdir("bc-p");
    let bdir = tdir("bc-b");
    let acked;
    {
        let net = Arc::new(LatencyModel::new(NetConfig::zero()));
        let primary =
            BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
        let bcfg = JournalConfig { checkpoint_every: 32, ..journal_cfg() };
        let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, bcfg).unwrap();
        backup.enable_backup_role();
        primary
            .set_backup(ChanTransport::new(backup.clone(), net, Arc::new(RpcMetrics::new())));

        let p = client_for(&primary, Arc::new(RpcMetrics::new()));
        acked = (0..48u32)
            .map(|i| {
                let (path, body) = (format!("/bc{i}"), format!("standby copy {i}").into_bytes());
                p.put(&path, &body).unwrap();
                (path, body)
            })
            .collect::<Vec<_>>();

        let pstats = primary.fs.journal().unwrap().stats().checkpoints.load(Ordering::Relaxed);
        let bstats = backup.fs.journal().unwrap().stats().checkpoints.load(Ordering::Relaxed);
        assert_eq!(pstats, 0, "the primary's default policy must not have fired");
        assert!(bstats >= 1, "the backup must compact its own journal, got {bstats}");
    }
    // the compacted standby journal alone still recovers everything
    let s2 = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    let p = client_for(&s2, Arc::new(RpcMetrics::new()));
    for (path, body) in &acked {
        let got = p
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("acked {path} lost in the compacted standby: {e:?}"));
        assert_eq!(&got, body, "{path} diverged through backup compaction");
    }
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
}

#[test]
fn journal_ship_refused_without_backup_role() {
    // Regression: JournalShip carries no credentials and bypasses every
    // permission check — any client could mutate server state by shipping
    // crafted frames. Only an explicitly enabled standby may accept it.
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    match s.handle(Request::JournalShip { frames: Vec::new() }) {
        Response::Err(FsError::PermissionDenied) => {}
        other => panic!("expected PermissionDenied, got {other:?}"),
    }
    s.enable_backup_role();
    match s.handle(Request::JournalShip { frames: Vec::new() }) {
        Response::Unit => {}
        other => panic!("expected Unit after enabling the role, got {other:?}"),
    }
}

#[test]
fn torn_journal_tail_is_truncated_and_clean_prefix_survives() {
    let dir = tdir("torn");
    {
        let s = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
        let p = client_for(&s, Arc::new(RpcMetrics::new()));
        p.put("/a", b"alpha").unwrap();
        p.put("/b", b"beta").unwrap();
    }
    // a crash mid-append leaves a torn frame: a header promising more
    // payload than the segment holds, then garbage
    let seg = dir.join("wal.0.log");
    let clean = std::fs::metadata(&seg).unwrap().len();
    let mut bytes = std::fs::read(&seg).unwrap();
    bytes.extend_from_slice(&[0x00, 0x04, 0x00, 0x00, 0xde, 0xad, 0xbe, 0xef, 0x55]);
    std::fs::write(&seg, &bytes).unwrap();

    let s2 = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    // the torn tail is physically gone: later appends extend the clean
    // prefix instead of burying garbage mid-segment
    assert_eq!(std::fs::metadata(&seg).unwrap().len(), clean, "torn tail must be truncated");
    let p = client_for(&s2, Arc::new(RpcMetrics::new()));
    assert_eq!(p.get("/a", 16).unwrap(), b"alpha");
    assert_eq!(p.get("/b", 16).unwrap(), b"beta");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Chaos suite (DESIGN.md §11): seeded drop/duplicate/delay/reorder faults,
// with and without a primary kill. The invariant is exactly-once: every
// acknowledged mutation is applied, and none is applied twice. Every path
// in the workload is unique to one (worker, iteration), so a spurious
// AlreadyExists or NotFound can ONLY come from a double-applied op.
// ---------------------------------------------------------------------------

/// Chaos runs replay a fixed seed by default; CI sweeps `CHAOS_SEED`.
fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xB0FFE7)
}

/// What the oracle knows about one acknowledged op's outcome. Ops whose
/// final RPC surfaced a (possibly injected) transport error are
/// indeterminate — recorded only as loosely as the truth allows.
enum Fate {
    /// Acked create/rename target: must exist.
    At(String),
    /// Acked unlink / rename source: must be gone.
    Gone(String),
    /// Rename whose ack was lost: the file is at exactly one of the two
    /// names — found at both (or neither) is a double-apply (or a loss).
    AtOneOf(String, String),
    /// Acked `put`: must exist with exactly these bytes.
    Bytes(String, Vec<u8>),
}

/// One chaos worker: create → rename → (every 3rd) unlink on paths
/// unique to this worker, with the occasional `put` to push a stamped
/// `WriteBatch` flush through the same machinery. Panics on the spot
/// when a double-apply surfaces; counts indeterminate ops in `errors`.
fn chaos_worker(p: &Buffet, w: u32, ops: u32, fates: &Mutex<Vec<Fate>>, errors: &AtomicU64) {
    let mut mine = Vec::new();
    for i in 0..ops {
        if i % 4 == 3 {
            let path = format!("/p{w}x{i}");
            let body = format!("chaos body {w}/{i}").into_bytes();
            match p.put(&path, &body) {
                Ok(()) => mine.push(Fate::Bytes(path, body)),
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
            continue;
        }
        let a = format!("/c{w}x{i}");
        let b = format!("/c{w}x{i}r");
        match p.create(&a, 0o644) {
            Ok(_) => {}
            Err(FsError::AlreadyExists) => {
                panic!("exactly-once violated: create {a} applied twice")
            }
            Err(_) => {
                errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        }
        match p.rename(&a, &b) {
            Ok(()) => {}
            Err(FsError::NotFound) => {
                panic!("exactly-once violated: rename {a} applied twice")
            }
            Err(_) => {
                errors.fetch_add(1, Ordering::Relaxed);
                mine.push(Fate::AtOneOf(a, b));
                continue;
            }
        }
        mine.push(Fate::Gone(a));
        if i % 3 == 0 {
            match p.unlink(&b) {
                Ok(()) => mine.push(Fate::Gone(b)),
                Err(FsError::NotFound) => {
                    panic!("exactly-once violated: unlink {b} applied twice")
                }
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        } else {
            mine.push(Fate::At(b));
        }
    }
    fates.lock().unwrap().extend(mine);
}

/// Verify every recorded fate against the surviving server through a
/// clean (fault-free) client.
fn sweep(p: &Buffet, fates: &[Fate]) {
    for f in fates {
        match f {
            Fate::At(path) => {
                p.stat(path).unwrap_or_else(|e| panic!("acked {path} lost: {e:?}"));
            }
            Fate::Gone(path) => match p.stat(path) {
                Err(FsError::NotFound) => {}
                other => panic!("acked removal of {path} undone: {other:?}"),
            },
            Fate::AtOneOf(a, b) => {
                let (at_a, at_b) = (p.stat(a).is_ok(), p.stat(b).is_ok());
                assert!(
                    at_a != at_b,
                    "exactly-once violated: {a}={at_a} {b}={at_b} (must be at exactly one)"
                );
            }
            Fate::Bytes(path, body) => {
                let got =
                    p.get(path, 1 << 16).unwrap_or_else(|e| panic!("acked {path} lost: {e:?}"));
                assert_eq!(&got, body, "{path} bytes diverged");
            }
        }
    }
}

#[test]
fn stamped_retry_is_answered_from_the_ledger() {
    // The deterministic core of the chaos suite: the very same stamped
    // rename delivered twice (a retransmit, or a retry after a lost
    // reply) answers identically both times and applies once.
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let p = client_for(&s, Arc::new(RpcMetrics::new()));
    p.put("/a", b"x").unwrap();
    let root = s.fs.root_ino();
    let stamped = Request::Stamped {
        client: 9,
        op_id: 1,
        ack_upto: 0,
        inner: Box::new(Request::Rename {
            sdir: root,
            sname: "a".into(),
            ddir: root,
            dname: "b".into(),
            cred: Credentials::root(),
        }),
    };
    let first = s.handle(stamped.clone());
    assert!(!matches!(first, Response::Err(_)), "first delivery must apply: {first:?}");
    let second = s.handle(stamped);
    assert_eq!(first, second, "retry must replay the cached reply verbatim");
    assert_eq!(s.ledger.hits.load(Ordering::Relaxed), 1);
    assert_eq!(s.ledger.misses.load(Ordering::Relaxed), 1);
    assert!(p.stat("/b").is_ok());
    assert_eq!(p.stat("/a").unwrap_err(), FsError::NotFound);

    // once the client acks past the op, its entry is pruned and a
    // too-late retry is called out as the protocol violation it is
    let late = s.handle(Request::Stamped {
        client: 9,
        op_id: 1,
        ack_upto: 1,
        inner: Box::new(Request::Rename {
            sdir: root,
            sname: "b".into(),
            ddir: root,
            dname: "c".into(),
            cred: Credentials::root(),
        }),
    });
    match late {
        Response::Err(FsError::Protocol(_)) => {}
        other => panic!("below-low-water retry must be refused, got {other:?}"),
    }
}

#[test]
fn chaos_storm_applies_every_mutation_exactly_once() {
    let dir = tdir("chaos-solo");
    let seed = chaos_seed();
    let fates;
    let errors = AtomicU64::new(0);
    let s = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let view = ClusterView::new(s.fs.root_ino());
    let faulty = FaultyTransport::new(
        ChanTransport::new(s.clone(), net, metrics.clone()),
        FaultConfig::chaos(seed),
    );
    view.add(0, 0, faulty.clone());
    let agent = BAgent::new(1, view, metrics);
    {
        let fates_mx = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for w in 0..8u32 {
                let agent = agent.clone();
                let (fates_mx, errors) = (&fates_mx, &errors);
                scope.spawn(move || {
                    let p = Buffet::with_pid(agent, 200 + w, Credentials::root());
                    chaos_worker(&p, w, 24, fates_mx, errors);
                });
            }
        });
        fates = fates_mx.into_inner().unwrap();
    }
    assert!(fates.len() > 100, "most ops must be acked, got {}", fates.len());
    // the run must actually have injected the evil cases…
    assert!(faulty.stats.dropped_replies.load(Ordering::Relaxed) > 0, "no reply drops injected");
    assert!(faulty.stats.duplicated.load(Ordering::Relaxed) > 0, "no duplicates injected");
    // …and the ledger must have absorbed them
    assert!(
        s.ledger.hits.load(Ordering::Relaxed) > 0,
        "chaos never exercised the dedup ledger (seed {seed})"
    );
    let p = client_for(&s, Arc::new(RpcMetrics::new()));
    sweep(&p, &fates);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_storm_with_primary_kill_loses_and_duplicates_nothing() {
    let pdir = tdir("chaos-prim");
    let bdir = tdir("chaos-back");
    let seed = chaos_seed();
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    backup.enable_backup_role();
    primary.set_backup(ChanTransport::new(backup.clone(), net.clone(), Arc::new(RpcMetrics::new())));

    let mut rng = XorShift::new(seed ^ 0x5EED);
    let kill = KillSwitch::arm(primary.clone(), 200 + rng.below(200));
    let metrics = Arc::new(RpcMetrics::new());
    let view = ClusterView::new(primary.fs.root_ino());
    view.add(
        0,
        0,
        FaultyTransport::new(
            ChanTransport::new(kill, net.clone(), metrics.clone()),
            FaultConfig::chaos(seed),
        ),
    );
    // the standby link is faulty too — failover lands on a lossy fabric
    view.register_standby(
        0,
        0,
        FaultyTransport::new(
            ChanTransport::new(backup.clone(), net, metrics.clone()),
            FaultConfig::chaos(seed.wrapping_add(1)),
        ),
    );
    let agent = BAgent::new(1, view, metrics.clone());

    let fates_mx = Mutex::new(Vec::new());
    let errors = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for w in 0..8u32 {
            let agent = agent.clone();
            let (fates_mx, errors) = (&fates_mx, &errors);
            scope.spawn(move || {
                let p = Buffet::with_pid(agent, 300 + w, Credentials::root());
                chaos_worker(&p, w, 24, fates_mx, errors);
            });
        }
    });
    let fates = fates_mx.into_inner().unwrap();
    assert!(metrics.failovers() >= 1, "the storm must have driven a promotion");
    assert!(fates.len() > 100, "most ops must be acked across the failover, got {}", fates.len());

    // every acked op — acked by the dead primary (shipped before the
    // reply) or by the promoted backup — is present exactly once
    let p = client_for(&backup, Arc::new(RpcMetrics::new()));
    sweep(&p, &fates);
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
}

#[test]
fn midlife_standby_catches_up_then_ships_live() {
    let pdir = tdir("catchup-p");
    let sdir = tdir("catchup-s");
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    let p = client_for(&primary, Arc::new(RpcMetrics::new()));
    let pre: Vec<(String, Vec<u8>)> = (0..32u32)
        .map(|i| {
            let (path, body) = (format!("/pre{i}"), format!("early {i}").into_bytes());
            p.put(&path, &body).unwrap();
            (path, body)
        })
        .collect();

    // a standby joins mid-life: pulls the whole history it missed…
    let standby = BServer::recover(0, 0, Box::new(MemData::new()), &sdir, journal_cfg()).unwrap();
    standby.enable_backup_role();
    primary.enable_replication_source();
    let pt: SharedTransport =
        ChanTransport::new(primary.clone(), net.clone(), Arc::new(RpcMetrics::new()));
    let (gen, offset, bytes, records) = standby.catch_up_from(&pt).unwrap();
    assert!(bytes > 0 && records > 0, "catch-up must pull the missed history");

    // …and is attached at its cursor: residual + live ship from here on
    let st: SharedTransport =
        ChanTransport::new(standby.clone(), net, Arc::new(RpcMetrics::new()));
    primary.attach_backup_at(st, gen, offset).unwrap();
    let post: Vec<(String, Vec<u8>)> = (0..8u32)
        .map(|i| {
            let (path, body) = (format!("/post{i}"), format!("live {i}").into_bytes());
            p.put(&path, &body).unwrap();
            (path, body)
        })
        .collect();

    // the standby serves everything — pre-join history and live tail
    let ps = client_for(&standby, Arc::new(RpcMetrics::new()));
    for (path, body) in pre.iter().chain(&post) {
        let got = ps
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("standby missing {path}: {e:?}"));
        assert_eq!(&got, body, "{path} diverged on the caught-up standby");
    }
    // and its journal is a byte-identical copy of the primary's stream
    let pj = std::fs::read(pdir.join("wal.0.log")).unwrap();
    let sj = std::fs::read(sdir.join("wal.0.log")).unwrap();
    assert_eq!(pj, sj, "caught-up standby journal must match the shipped stream");
    let j = primary.fs.journal().unwrap();
    assert!(j.stats().catchup_bytes.load(Ordering::Relaxed) > 0);
    assert!(j.stats().catchup_records.load(Ordering::Relaxed) > 0);
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&sdir);
}

#[test]
fn promotion_recruits_and_reseeds_a_fresh_standby() {
    let pdir = tdir("reseed-p");
    let bdir = tdir("reseed-b");
    let sdir = tdir("reseed-s");
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    backup.enable_backup_role();
    primary.set_backup(ChanTransport::new(backup.clone(), net.clone(), Arc::new(RpcMetrics::new())));
    let spare = BServer::recover(0, 0, Box::new(MemData::new()), &sdir, journal_cfg()).unwrap();

    let metrics = Arc::new(RpcMetrics::new());
    let kill = KillSwitch::arm(primary.clone(), 40);
    let view = ClusterView::new(primary.fs.root_ino());
    view.add(0, 0, ChanTransport::new(kill, net.clone(), metrics.clone()));
    view.register_standby(
        0,
        0,
        ChanTransport::new(backup.clone(), net.clone(), metrics.clone()),
    );
    // Self-healing: when a promotion consumes the standby, recruit the
    // spare — catch it up from the new primary's journal and attach it
    // as the live backup, all before the failed-over op completes.
    let backup_t: SharedTransport =
        ChanTransport::new(backup.clone(), net.clone(), Arc::new(RpcMetrics::new()));
    let spare_t: SharedTransport =
        ChanTransport::new(spare.clone(), net.clone(), Arc::new(RpcMetrics::new()));
    let (rb, rs) = (backup.clone(), spare.clone());
    view.set_recruiter(Arc::new(move |host: HostId, _version: Version| {
        if host != 0 {
            return None;
        }
        rb.enable_replication_source();
        rs.enable_backup_role();
        let (gen, offset, _, _) = rs.catch_up_from(&backup_t).ok()?;
        rb.attach_backup_at(spare_t.clone(), gen, offset).ok()?;
        Some(spare_t.clone())
    }));
    let agent = BAgent::new(1, view, metrics.clone());
    let p = Buffet::process(agent.clone(), Credentials::root());

    // the kill fires mid-run; with exactly-once stamping EVERY put must
    // still succeed — no op surfaces the crash to the application
    let all: Vec<(String, Vec<u8>)> = (0..80u32)
        .map(|i| {
            let (path, body) = (format!("/r{i}"), format!("reseed {i}").into_bytes());
            p.put(&path, &body).unwrap_or_else(|e| panic!("put {path} across failover: {e:?}"));
            (path, body)
        })
        .collect();
    assert!(metrics.failovers() >= 1, "the kill must have driven a promotion");
    assert!(agent.cluster().has_standby(0), "promotion must have recruited a fresh standby");

    // the promoted backup has everything; so does the reseeded spare
    // (caught up + live-shipped), which is what makes the heal real
    for (server, tag) in [(&backup, "promoted backup"), (&spare, "reseeded spare")] {
        let c = client_for(server, Arc::new(RpcMetrics::new()));
        for (path, body) in &all {
            let got =
                c.get(path, 1 << 16).unwrap_or_else(|e| panic!("{tag} missing {path}: {e:?}"));
            assert_eq!(&got, body, "{path} diverged on the {tag}");
        }
    }
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
    let _ = std::fs::remove_dir_all(&sdir);
}

#[test]
fn replaying_the_same_journal_twice_is_idempotent() {
    let dir = tdir("double");
    {
        let s = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
        let p = client_for(&s, Arc::new(RpcMetrics::new()));
        p.mkdir("/d", 0o755).unwrap();
        p.put("/d/f", b"one").unwrap();
        p.put("/g", b"two").unwrap();
        p.chmod("/g", 0o600).unwrap();
        p.rename("/g", "/d/h").unwrap();
        p.put("/gone", b"x").unwrap();
        p.unlink("/gone").unwrap();
    }
    let observe = |s: &Arc<BServer>| {
        let p = client_for(s, Arc::new(RpcMetrics::new()));
        let f = p.stat("/d/f").unwrap();
        let h = p.stat("/d/h").unwrap();
        assert_eq!(p.get("/d/f", 16).unwrap(), b"one");
        assert_eq!(p.get("/d/h", 16).unwrap(), b"two");
        assert_eq!(p.stat("/gone").unwrap_err(), FsError::NotFound);
        (f.ino, f.size, h.ino, h.size)
    };
    // recovery does not consume the journal: replaying the very same
    // segment into a second fresh incarnation converges on the same
    // state, same inos and all
    let s1 = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    let first = observe(&s1);
    drop(s1);
    let s2 = BServer::recover(0, 0, Box::new(MemData::new()), &dir, journal_cfg()).unwrap();
    let second = observe(&s2);
    assert_eq!(first, second, "double replay diverged");
    let _ = std::fs::remove_dir_all(&dir);
}
