//! Handle-first client API acceptance: `Dir`/`File` capability handles
//! with openat-style relative ops and permission leases.
//!
//! * warm same-directory sibling opens via `Dir::open_file` perform
//!   ZERO resolve RPCs (in fact zero RPCs at all);
//! * a post-`chmod` stale lease triggers exactly ONE re-resolve retry
//!   (observable in the per-op metrics);
//! * `rename` of an open `Dir`'s ancestor keeps relative ops correct
//!   (handles address the namespace by node, not by path).

use std::sync::atomic::Ordering;
use std::time::Duration;

use buffetfs::api::Client;
use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};

fn fast_cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 11 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

fn quiesce(metrics: &buffetfs::metrics::RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

#[test]
fn warm_sibling_opens_cost_zero_resolve_rpcs() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let pool = root.mkdir("pool", 0o777).unwrap();

    let user = Client::new(agent.clone(), Credentials::new(1000, 1000));
    let upool = user.root().unwrap().open_dir("pool").unwrap();
    for i in 0..16 {
        upool.create(&format!("f{i}"), 0o644).unwrap().close().unwrap();
    }
    let _ = upool.readdir().unwrap(); // warm + register the listing once
    quiesce(&metrics);

    let resolves = metrics.count("resolve");
    let total = metrics.total_rpcs();
    let hits_before = metrics.lease_hits("open");
    for i in 0..16 {
        let f = upool.open_file(&format!("f{i}"), OpenFlags::RDONLY).unwrap();
        f.close().unwrap();
    }
    assert_eq!(
        metrics.count("resolve"),
        resolves,
        "warm sibling opens must issue ZERO resolve RPCs"
    );
    assert_eq!(metrics.total_rpcs(), total, "…in fact zero RPCs of any kind");
    assert!(
        metrics.lease_hits("open") >= hits_before + 16,
        "every relative open served under the lease"
    );
    assert_eq!(metrics.stale_retries("open"), 0, "nothing was revoked");
    assert!(agent.stats.rpc_free_opens.load(Ordering::Relaxed) >= 16);
}

#[test]
fn chmod_on_ancestor_triggers_exactly_one_stale_retry() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let a = root.mkdir("a", 0o755).unwrap();
    let b = a.mkdir("b", 0o777).unwrap();
    b.create("f", 0o644).unwrap().close().unwrap();
    let _ = b.readdir().unwrap(); // warm + register b's listing
    // warm open once so the steady state is established
    b.open_file("f", OpenFlags::RDONLY).unwrap().close().unwrap();
    quiesce(&metrics);

    // chmod of the ANCESTOR /a: pushes §3.4 invalidations at this agent,
    // making every handle's client-side lease conservatively stale
    let legacy = Buffet::process(agent.clone(), Credentials::root());
    legacy.chmod("/a", 0o751).unwrap();
    quiesce(&metrics);

    let stale_before = metrics.stale_retries("open");
    let resolves = metrics.count("resolve");
    let leases = metrics.count("lease");
    let f = b.open_file("f", OpenFlags::RDONLY).unwrap();
    f.close().unwrap();
    assert_eq!(
        metrics.stale_retries("open"),
        stale_before + 1,
        "the post-chmod open must pay exactly one stale-lease retry"
    );
    assert_eq!(
        metrics.count("lease"),
        leases + 1,
        "the re-resolve is ONE Lease RPC (not a root walk)"
    );
    assert_eq!(metrics.count("resolve"), resolves, "no ResolvePath issued");
    assert!(agent.stats.stale_lease_retries.load(Ordering::Relaxed) <= 1);

    // steady state restored: the next sibling open is free again
    let total = metrics.total_rpcs();
    b.open_file("f", OpenFlags::RDONLY).unwrap().close().unwrap();
    assert_eq!(metrics.total_rpcs(), total, "one retry, then back to zero-RPC opens");
}

#[test]
fn rename_of_open_dirs_ancestor_keeps_relative_ops_correct() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let a = root.mkdir("a", 0o755).unwrap();
    let b = a.mkdir("b", 0o755).unwrap();
    let f = b.create("f", 0o644).unwrap();
    f.write_at(0, b"payload").unwrap();
    f.close().unwrap();
    quiesce(&metrics);

    // rename the ANCESTOR /a → /a2 while the b handle stays open
    let legacy = Buffet::process(agent.clone(), Credentials::root());
    legacy.rename("/a", "/a2").unwrap();
    quiesce(&metrics);

    // the b handle addresses its node, not its path: relative ops work
    let f = b.open_file("f", OpenFlags::RDONLY).unwrap();
    assert_eq!(f.read_at(0, 16).unwrap(), b"payload");
    f.close().unwrap();
    b.create("g", 0o644).unwrap().close().unwrap();
    assert_eq!(b.stat("g").unwrap().perm.mode.0, 0o644);

    // and the new path resolves to the same content through the legacy API
    let p = Buffet::process(agent.clone(), Credentials::root());
    assert_eq!(p.get("/a2/b/f", 16).unwrap(), b"payload");
    assert_eq!(p.open("/a/b/f", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);
}

#[test]
fn chmod_of_the_dir_itself_revokes_the_capability() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let private = root.mkdir("private", 0o755).unwrap();
    private.create("f", 0o644).unwrap().close().unwrap();

    let user = Client::new(agent.clone(), Credentials::new(700, 700));
    let upriv = user.root().unwrap().open_dir("private").unwrap();
    upriv.open_file("f", OpenFlags::RDONLY).unwrap().close().unwrap();
    quiesce(&metrics);

    // revoke world-X on the directory: the capability must die at the
    // next lease validation — the server refuses the re-grant
    let legacy = Buffet::process(agent.clone(), Credentials::root());
    legacy.chmod("/private", 0o700).unwrap();
    quiesce(&metrics);
    assert_eq!(
        upriv.open_file("f", OpenFlags::RDONLY).unwrap_err(),
        FsError::PermissionDenied,
        "revoked dir: the stale lease may not be refreshed"
    );
    // loosening restores it (the §3.4 push re-invalidates, re-grant works)
    legacy.chmod("/private", 0o755).unwrap();
    quiesce(&metrics);
    let f = upriv.open_file("f", OpenFlags::RDONLY).unwrap();
    f.close().unwrap();
}

#[test]
fn handle_api_full_namespace_cycle() {
    let cluster = fast_cluster();
    let (agent, _metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let work = root.mkdir("work", 0o755).unwrap();

    // create + write + read through File handles
    let f = work.create("data.bin", 0o644).unwrap();
    assert_eq!(f.write_at(0, b"hello handles").unwrap(), 13);
    assert_eq!(f.read_at(6, 7).unwrap(), b"handles");
    f.truncate(5).unwrap();
    f.close().unwrap();
    assert_eq!(work.stat("data.bin").unwrap().size, 5);

    // readdir sees it; rename_into moves it between handles
    let names: Vec<String> = work.readdir().unwrap().into_iter().map(|e| e.name).collect();
    assert_eq!(names, vec!["data.bin".to_string()]);
    let archive = root.mkdir("archive", 0o755).unwrap();
    work.rename_into("data.bin", &archive, "data.old").unwrap();
    assert_eq!(work.readdir().unwrap().len(), 0);
    let f = archive.open_file("data.old", OpenFlags::RDONLY).unwrap();
    assert_eq!(f.read_at(0, 16).unwrap(), b"hello");
    f.close().unwrap();

    // unlink + rmdir complete the cycle
    archive.unlink("data.old").unwrap();
    assert_eq!(archive.stat("data.old").unwrap_err(), FsError::NotFound);
    root.rmdir("archive").unwrap();
    root.rmdir("work").unwrap();
    assert_eq!(root.open_dir("work").unwrap_err(), FsError::NotFound);

    // O_CREAT through open_file works relative too
    let scratch = root.mkdir("scratch", 0o777).unwrap();
    let user = Client::new(agent, Credentials::new(9, 9));
    let uscratch = user.root().unwrap().open_dir("scratch").unwrap();
    let f = uscratch.open_file("new.txt", OpenFlags::RDWR.with_create()).unwrap();
    f.write_at(0, b"x").unwrap();
    f.close().unwrap();
    assert_eq!(uscratch.stat("new.txt").unwrap().size, 1);
}

#[test]
fn x_only_dir_falls_back_to_relative_openat() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let vault = root.mkdir("vault", 0o711).unwrap(); // others: x only
    let f = vault.create("known", 0o644).unwrap();
    f.write_at(0, b"k").unwrap();
    f.close().unwrap();
    quiesce(&metrics);

    let user = Client::new(agent.clone(), Credentials::new(55, 55));
    let uvault = user.root().unwrap().open_dir("vault").unwrap();
    // cannot list…
    assert_eq!(uvault.readdir().unwrap_err(), FsError::PermissionDenied);
    // …but can open a known name through the capability (OpenAt RPC)
    let f = uvault.open_file("known", OpenFlags::RDONLY).unwrap();
    assert_eq!(f.read_at(0, 4).unwrap(), b"k");
    f.close().unwrap();
}
