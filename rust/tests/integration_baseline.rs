//! Lustre baseline integration: MDS + OSS semantics, intent opens, the
//! DoM inline path, LDLM interplay — and the RPC-schedule comparison
//! against BuffetFS that underlies every figure.

use buffetfs::baseline::{LustreCluster, LustreMode};
use buffetfs::cluster::Backing;
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};

fn cluster(mode: LustreMode) -> LustreCluster {
    LustreCluster::spawn_with(
        4,
        mode,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 1 },
        Backing::Mem,
        ServiceConfig::unbounded(),
    )
}

#[test]
fn normal_mode_schedule_is_two_sync_rpcs() {
    let c = cluster(LustreMode::Normal);
    let (client, metrics) = c.make_client();
    let root = Credentials::root();
    client.put(1, "/f.dat", &[7u8; 4096], &root).unwrap();

    // warm access: intent-open (1) + OSS read (1); close is async
    client.get(1, "/f.dat", 4096, &root).unwrap();
    metrics.reset();
    let data = client.get(1, "/f.dat", 4096, &root).unwrap();
    assert_eq!(data.len(), 4096);
    assert_eq!(metrics.count("open"), 1, "every Lustre access opens at the MDS");
    assert_eq!(metrics.count("read"), 1, "data comes from the OSS");
    assert_eq!(metrics.count("lookup"), 0, "warm dentries need no lookups");
    assert_eq!(metrics.sync_rpcs(), 2);
}

#[test]
fn dom_mode_inlines_reads_but_not_writes() {
    let c = cluster(LustreMode::dom_default());
    let (client, metrics) = c.make_client();
    let root = Credentials::root();
    client.put(1, "/small", &[1u8; 2048], &root).unwrap();

    client.get(1, "/small", 2048, &root).unwrap();
    metrics.reset();
    // read path: ONE sync RPC (open carries the data)
    let data = client.get(1, "/small", 2048, &root).unwrap();
    assert_eq!(data, vec![1u8; 2048]);
    assert_eq!(metrics.count("open"), 1);
    assert_eq!(metrics.count("read"), 0, "DoM read served from the open reply");
    assert_eq!(metrics.sync_rpcs(), 1);
    assert!(c.mds.stats.inline_reads_served.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    // write path: the MDS absorbs the data (the §5 congestion point)
    metrics.reset();
    let before = c.mds.stats.inline_writes_absorbed.load(std::sync::atomic::Ordering::Relaxed);
    client.put(1, "/small", &[2u8; 2048], &root).unwrap();
    assert!(c.mds.stats.inline_writes_absorbed.load(std::sync::atomic::Ordering::Relaxed) > before);
    // and OSSes stored nothing
    assert!(c.osses.iter().all(|o| o.bytes_stored() == 0));
}

#[test]
fn normal_mode_spreads_data_over_osses() {
    let c = cluster(LustreMode::Normal);
    let (client, _) = c.make_client();
    let root = Credentials::root();
    for i in 0..32 {
        client.put(1, &format!("/f{i}"), &[3u8; 1024], &root).unwrap();
    }
    let stored: Vec<u64> = c.osses.iter().map(|o| o.bytes_stored()).collect();
    assert_eq!(stored.iter().sum::<u64>(), 32 * 1024);
    assert!(stored.iter().filter(|&&b| b > 0).count() >= 3, "striping too skewed: {stored:?}");
    // MDS holds no file data in Normal mode
    let (_, mds_bytes) = c.mds.fs.statfs();
    assert_eq!(mds_bytes, 0);
}

#[test]
fn server_side_permission_check_costs_a_round_trip() {
    let c = cluster(LustreMode::Normal);
    let (client, metrics) = c.make_client();
    let root = Credentials::root();
    client.put(1, "/guarded", b"x", &root).unwrap();
    client.chmod("/guarded", 0o600, &root).unwrap();

    let stranger = Credentials::new(9, 9);
    metrics.reset();
    let err = client.open(1, "/guarded", OpenFlags::RDONLY, &stranger).unwrap_err();
    assert_eq!(err, FsError::PermissionDenied);
    // unlike BuffetFS, the denial burned a full MDS round trip
    assert_eq!(metrics.count("open"), 1);
}

#[test]
fn dentry_cache_avoids_lookup_rpcs() {
    let c = cluster(LustreMode::Normal);
    let (client, metrics) = c.make_client();
    let root = Credentials::root();
    client.mkdir("/deep", 0o755, &root).unwrap();
    client.mkdir("/deep/er", 0o755, &root).unwrap();
    client.put(1, "/deep/er/f", b"x", &root).unwrap();

    client.get(1, "/deep/er/f", 1, &root).unwrap();
    let misses = client.stats.dentry_misses.load(std::sync::atomic::Ordering::Relaxed);
    client.get(1, "/deep/er/f", 1, &root).unwrap();
    client.get(1, "/deep/er/f", 1, &root).unwrap();
    assert_eq!(
        client.stats.dentry_misses.load(std::sync::atomic::Ordering::Relaxed),
        misses,
        "warm dentries must not miss"
    );
    assert_eq!(metrics.count("lookup"), 0, "intent opens subsume leaf lookups");
}

#[test]
fn ldlm_locks_cached_and_revoked_between_clients() {
    let c = cluster(LustreMode::Normal);
    let (a, _) = c.make_client();
    let (b, _) = c.make_client();
    let root = Credentials::root();
    a.put(1, "/locked", &[1u8; 64], &root).unwrap();

    // A reads twice: one grant, one cache hit
    a.get(1, "/locked", 64, &root).unwrap();
    a.get(1, "/locked", 64, &root).unwrap();
    let la = a.ldlm.as_ref().unwrap();
    assert!(la.stats.cache_hits.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    // B writes: exclusive grant revokes A's shared lock
    b.put(1, "/locked", &[2u8; 64], &root).unwrap();
    assert!(c.lockspace.revocations.load(std::sync::atomic::Ordering::Relaxed) >= 1);

    // A reads again → re-grant (its cache entry was revoked)
    let grants_before = la.stats.grant_rpcs.load(std::sync::atomic::Ordering::Relaxed);
    a.get(1, "/locked", 64, &root).unwrap();
    assert!(la.stats.grant_rpcs.load(std::sync::atomic::Ordering::Relaxed) > grants_before);
}

#[test]
fn create_through_open_with_ocreat() {
    let c = cluster(LustreMode::Normal);
    let (client, _) = c.make_client();
    let root = Credentials::root();
    let fd = client.open(1, "/fresh", OpenFlags::RDWR.with_create(), &root).unwrap();
    client.write(1, fd, b"new").unwrap();
    client.close(1, fd).unwrap();
    assert_eq!(client.get(1, "/fresh", 16, &root).unwrap(), b"new");
}
