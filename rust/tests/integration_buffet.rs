//! Full-stack BuffetFS integration: BLib → BAgent → transport → BServer
//! → store, over the latency-injected channel transport.

use std::sync::atomic::Ordering;
use std::time::Duration;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, FileKind, OpenFlags};

/// Wait for background async-close traffic to drain so RPC counters and
/// the opened-file list are stable before an assertion window.
fn quiesce(cluster: &BuffetCluster, metrics: &buffetfs::metrics::RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last && cluster.servers.iter().map(|s| s.open_files()).sum::<usize>() == 0 {
            return;
        }
        last = now;
    }
}

fn fast_cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        2,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 1 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

#[test]
fn open_costs_zero_rpcs_when_warm() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/w", 0o755).unwrap();
    for i in 0..10 {
        admin.put(&format!("/w/f{i}"), b"0123456789").unwrap();
    }
    admin.get("/w/f0", 10).unwrap(); // warm the tree
    quiesce(&cluster, &metrics); // async closes must drain before counting

    let before = metrics.total_rpcs();
    let fd = admin.open("/w/f7", OpenFlags::RDONLY).unwrap();
    assert_eq!(metrics.total_rpcs(), before, "warm open must be RPC-free");
    let data = admin.read(fd, 10).unwrap();
    assert_eq!(data, b"0123456789");
    assert_eq!(metrics.total_rpcs(), before + 1, "read carries the deferred open");
    admin.close(fd).unwrap();
    assert!(agent.stats.rpc_free_opens.load(Ordering::Relaxed) >= 1);
}

#[test]
fn denied_open_is_free_and_correct() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/p", 0o755).unwrap();
    admin.put("/p/secret", b"top").unwrap();
    admin.chmod("/p/secret", 0o600).unwrap();

    let user = Buffet::process(agent.clone(), Credentials::new(777, 777));
    user.stat("/p/secret").ok(); // warm (stat itself is allowed: x on dirs)
    quiesce(&cluster, &metrics);
    let before = metrics.total_rpcs();
    assert_eq!(user.open("/p/secret", OpenFlags::RDONLY).unwrap_err(), FsError::PermissionDenied);
    assert_eq!(metrics.total_rpcs(), before, "local denial must not produce RPCs");
    assert!(agent.stats.local_denies.load(Ordering::Relaxed) >= 1);
}

#[test]
fn open_close_without_io_never_contacts_server() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Buffet::process(agent, Credentials::root());
    admin.put("/nop", b"x").unwrap();
    admin.get("/nop", 1).unwrap();
    quiesce(&cluster, &metrics);
    let before = metrics.total_rpcs();
    let fd = admin.open("/nop", OpenFlags::RDONLY).unwrap();
    admin.close(fd).unwrap();
    std::thread::sleep(Duration::from_millis(30)); // let any async close drain
    assert_eq!(metrics.total_rpcs(), before, "no I/O → no server-side open → no close RPC");
    assert_eq!(cluster.servers[0].open_files(), 0);
}

#[test]
fn openlist_settles_after_close() {
    let cluster = fast_cluster();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.put("/f", &[9u8; 128]).unwrap();
    // the put's async close must drain before we count openers
    for _ in 0..100 {
        if cluster.servers[0].open_files() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let fd1 = p.open("/f", OpenFlags::RDONLY).unwrap();
    let fd2 = p.open("/f", OpenFlags::RDONLY).unwrap();
    p.read(fd1, 8).unwrap();
    p.read(fd2, 8).unwrap();
    let file = p.stat("/f").unwrap().ino.file;
    assert_eq!(cluster.servers[0].openers_of(file), 2);
    p.close(fd1).unwrap();
    p.close(fd2).unwrap();
    // close wrap-up is asynchronous — poll for it
    for _ in 0..100 {
        if cluster.servers[0].openers_of(file) == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("opened-file list never drained");
}

#[test]
fn posix_file_semantics() {
    let cluster = fast_cluster();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/d", 0o755).unwrap();

    // sequential read/write offsets
    let fd = p.open("/d/f", OpenFlags::RDWR.with_create()).unwrap();
    p.write(fd, b"hello ").unwrap();
    p.write(fd, b"world").unwrap();
    p.close(fd).unwrap();
    assert_eq!(p.get("/d/f", 64).unwrap(), b"hello world");

    // pread/pwrite
    let fd = p.open("/d/f", OpenFlags::RDWR).unwrap();
    p.pwrite(fd, 6, b"WORLD").unwrap();
    assert_eq!(p.pread(fd, 0, 64).unwrap(), b"hello WORLD");
    p.close(fd).unwrap();

    // truncate via open flag
    let fd = p.open("/d/f", OpenFlags::WRONLY.with_truncate()).unwrap();
    p.close(fd).unwrap();
    assert_eq!(p.stat("/d/f").unwrap().size, 0);

    // append
    let fd = p.open("/d/f", OpenFlags::WRONLY.with_append()).unwrap();
    p.write(fd, b"aa").unwrap();
    p.close(fd).unwrap();
    let fd = p.open("/d/f", OpenFlags::WRONLY.with_append()).unwrap();
    p.write(fd, b"bb").unwrap();
    p.close(fd).unwrap();
    assert_eq!(p.get("/d/f", 64).unwrap(), b"aabb");

    // bad fd
    assert_eq!(p.read(12345, 1).unwrap_err(), FsError::BadFd);

    // readdir sees both perm blobs and names
    let entries = p.readdir("/d").unwrap();
    assert_eq!(entries.len(), 1);
    assert_eq!(entries[0].name, "f");
    assert_eq!(entries[0].kind, FileKind::Regular);
}

#[test]
fn namespace_ops_full_cycle() {
    let cluster = fast_cluster();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/a", 0o755).unwrap();
    p.mkdir("/a/b", 0o755).unwrap();
    p.put("/a/b/one", b"1").unwrap();

    // rename within the same server
    p.rename("/a/b/one", "/a/b/uno").unwrap();
    assert_eq!(p.get("/a/b/uno", 4).unwrap(), b"1");
    assert_eq!(p.open("/a/b/one", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);

    // unlink + enoent
    p.unlink("/a/b/uno").unwrap();
    assert_eq!(p.stat("/a/b/uno").unwrap_err(), FsError::NotFound);

    // rmdir requires empty
    p.put("/a/b/two", b"2").unwrap();
    assert_eq!(p.rmdir("/a/b").unwrap_err(), FsError::NotEmpty);
    p.unlink("/a/b/two").unwrap();
    p.rmdir("/a/b").unwrap();
    assert_eq!(p.readdir("/a").unwrap().len(), 0);
}

#[test]
fn authoritative_local_enoent_and_resolution_errors() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/dir", 0o755).unwrap();
    p.put("/dir/real", b"x").unwrap();
    p.readdir("/dir").unwrap(); // cache the listing
    quiesce(&cluster, &metrics);
    let before = metrics.total_rpcs();
    assert_eq!(p.open("/dir/ghost", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);
    assert_eq!(metrics.total_rpcs(), before, "cached ENOENT must be served locally");

    // path through a file is ENOTDIR
    assert_eq!(p.open("/dir/real/xx", OpenFlags::RDONLY).unwrap_err(), FsError::NotADirectory);
    // relative paths rejected
    assert!(matches!(p.open("dir/real", OpenFlags::RDONLY).unwrap_err(), FsError::Invalid(_)));
}

#[test]
fn x_only_traversal_falls_back_to_lookup() {
    let cluster = fast_cluster();
    let (agent, _) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/vault", 0o711).unwrap(); // others: x only
    admin.put("/vault/known", b"k").unwrap();
    admin.chmod("/vault/known", 0o644).unwrap();

    let user = Buffet::process(agent.clone(), Credentials::new(55, 55));
    // cannot list the vault…
    assert_eq!(user.readdir("/vault").unwrap_err(), FsError::PermissionDenied);
    // …but can open a known name through it
    let data = user.get("/vault/known", 4).unwrap();
    assert_eq!(data, b"k");
    assert!(agent.stats.fallback_lookups.load(Ordering::Relaxed) >= 1);
}
