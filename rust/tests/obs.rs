//! Unified telemetry plane acceptance (DESIGN.md §13).
//!
//! The invariants under test:
//! * one top-level op yields ONE causally-linked trace tree spanning the
//!   client ring and the server ring — across chan AND tcp transports;
//! * a `WrongServer` redirect and a failover retry stay inside the op's
//!   single trace, each annotated with its retry class;
//! * a legacy peer that rejects the `Traced` envelope sticky-downgrades
//!   the agent to untraced requests without erroring the op;
//! * ring overwrite never evicts slow-op entries, and `SEC_SLOW` drains
//!   them remotely;
//! * a `StatsFetch` snapshot reconciles with the client's `RpcMetrics`
//!   ground truth.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use buffetfs::agent::BAgent;
use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster, ClusterView};
use buffetfs::error::FsError;
use buffetfs::metrics::{RpcMetrics, OPS};
use buffetfs::obs::{Span, RING_CAP, SEC_OPS, SEC_SERVER, SEC_SLOW};
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::{ChanNotify, ChanTransport};
use buffetfs::transport::tcp::{ReconnectConfig, ReconnectTransport, TcpServer};
use buffetfs::transport::{Service, Transport};
use buffetfs::types::{Credentials, OpenFlags};
use buffetfs::wire::{Request, Response};

fn fast_cluster(n: u16) -> BuffetCluster {
    BuffetCluster::spawn_with(
        n,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

/// Wait for in-flight async traffic (deferred closes) to retire.
fn quiesce(metrics: &RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..200 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

/// All spans of `trace_id`, client ring first, then the given server
/// rings.
fn whole_trace(agent: &Arc<BAgent>, servers: &[&Arc<BServer>], trace_id: u64) -> Vec<Span> {
    let mut spans = agent.tracer().trace(trace_id);
    for s in servers {
        spans.extend(s.obs.trace.trace(trace_id));
    }
    spans
}

/// A trace is a single causal tree: exactly one root, and every other
/// span's parent is present in the trace.
fn assert_single_tree(spans: &[Span]) {
    let ids: std::collections::BTreeSet<u64> = spans.iter().map(|s| s.span_id).collect();
    let roots: Vec<&Span> = spans.iter().filter(|s| s.parent == 0).collect();
    assert_eq!(roots.len(), 1, "exactly one root, got {roots:?}");
    for s in spans {
        if s.parent != 0 {
            assert!(
                ids.contains(&s.parent),
                "span {} ({}) orphaned: parent {} not in trace",
                s.span_id,
                s.name,
                s.parent
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Trace tree, client → server
// ---------------------------------------------------------------------------

#[test]
fn cold_open_yields_one_linked_trace_tree_over_chan() {
    let cluster = fast_cluster(1);
    let admin = {
        let (agent, _) = cluster.make_agent();
        Buffet::process(agent, Credentials::root())
    };
    admin.mkdir("/d", 0o755).unwrap();
    admin.put("/d/f", b"payload").unwrap();

    let (agent, _metrics) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    let fd = p.open("/d/f", OpenFlags::RDONLY).unwrap();
    assert_eq!(p.read(fd, 7).unwrap(), b"payload");
    p.close(fd).unwrap();

    // the open's root span anchors the trace
    let root = agent
        .tracer()
        .snapshot()
        .into_iter()
        .find(|s| s.name == "open" && s.parent == 0)
        .expect("the open op must record a root span");
    let server = cluster.server(0).unwrap();
    let spans = whole_trace(&agent, &[&server], root.trace_id);
    assert!(spans.len() >= 3, "open must record more than the root: {spans:?}");
    assert_single_tree(&spans);
    assert!(
        spans.iter().any(|s| !s.server && s.parent == root.span_id),
        "the open must have issued at least one client rpc span"
    );
    let server_spans: Vec<&Span> = spans.iter().filter(|s| s.server).collect();
    assert!(!server_spans.is_empty(), "the server side must have joined the trace");
    let client_ids: std::collections::BTreeSet<u64> =
        spans.iter().filter(|s| !s.server).map(|s| s.span_id).collect();
    for s in &server_spans {
        assert!(
            client_ids.contains(&s.parent),
            "server span {} must hang off a client rpc span",
            s.name
        );
    }
}

#[test]
fn trace_ctx_rides_tcp_framing_and_statsfetch_scrapes_it() {
    let server = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let _tcp =
        TcpServer::spawn_obs("127.0.0.1:0", server.clone(), Some(server.obs.clone())).expect("bind");
    let addr = _tcp.local_addr.to_string();
    let root = server.fs.root_ino();
    let metrics = Arc::new(RpcMetrics::new());

    // pipelined framing: the ctx travels as a FLAG_TRACE header extension
    let cfg = ReconnectConfig { pipelined: true, ..ReconnectConfig::default() };
    let piped = ReconnectTransport::connect(&addr, cfg, metrics.clone()).unwrap();
    piped
        .call(Request::Traced {
            trace_id: 4242,
            parent_span: 17,
            inner: Box::new(Request::GetAttr { ino: root }),
        })
        .expect("traced getattr over pipelined tcp");

    // lockstep framing: the whole envelope travels in the payload
    let lock = ReconnectTransport::connect(&addr, ReconnectConfig::default(), metrics).unwrap();
    lock.call(Request::Traced {
        trace_id: 4243,
        parent_span: 18,
        inner: Box::new(Request::GetAttr { ino: root }),
    })
    .expect("traced getattr over lockstep tcp");

    // both attempts executed exactly once, counted under the INNER op
    assert_eq!(server.obs.dispatch_count("getattr"), 2);

    // the remote scrape returns each trace with its wire-carried lineage
    for (trace_id, parent) in [(4242u64, 17u64), (4243, 18)] {
        match lock.call(Request::StatsFetch { sections: 0, trace_id }).unwrap() {
            Response::Stats { spans, .. } => {
                let s = spans
                    .iter()
                    .find(|s| s.trace_id == trace_id)
                    .unwrap_or_else(|| panic!("trace {trace_id} missing from scrape"));
                assert_eq!(s.parent, parent, "server span must parent under the wire ctx");
                assert_eq!(s.name, "getattr");
                assert!(s.server);
                assert_eq!(s.host, 0);
            }
            other => panic!("stats fetch returned {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Retry classes stay inside one trace
// ---------------------------------------------------------------------------

#[test]
fn wrong_server_redirect_is_one_annotated_trace() {
    let cluster = fast_cluster(2);
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.put("/hot/f0", b"before").unwrap();
    let hot = p.stat("/hot").unwrap().ino;

    match cluster.server(0).unwrap().handle(Request::MigrateSubtree {
        dir: hot,
        target: 1,
        grace: 0,
    }) {
        Response::Migrated { .. } => {}
        other => panic!("migration failed: {other:?}"),
    }

    // stale placement cache: the next mutation pays one WrongServer hop
    p.put("/hot/f1", b"after").unwrap();
    assert!(agent.stats.redirects.load(Ordering::Relaxed) >= 1);

    let redirected = agent
        .tracer()
        .snapshot()
        .into_iter()
        .find(|s| s.note.contains("wrong_server->1"))
        .expect("the redirected attempt must be annotated");
    let s1 = cluster.server(1).unwrap();
    let spans = whole_trace(&agent, &[&s1], redirected.trace_id);
    assert_single_tree(&spans);
    assert!(
        spans.iter().any(|s| s.server && s.host == 1),
        "the retried attempt must appear in host 1's ring under the SAME trace: {spans:?}"
    );
}

/// Answers like a live server until `dead` flips, then like a severed
/// connection.
struct KillSwitch {
    inner: Arc<BServer>,
    dead: AtomicBool,
}

impl Service for KillSwitch {
    fn handle(&self, req: Request) -> Response {
        if self.dead.load(Ordering::Acquire) {
            return Response::Err(FsError::Transport("primary crashed".into()));
        }
        self.inner.handle(req)
    }
}

#[test]
fn failover_retry_is_one_annotated_trace() {
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let kill = Arc::new(KillSwitch { inner: s.clone(), dead: AtomicBool::new(false) });
    let view = ClusterView::new(s.fs.root_ino());
    view.add(0, 0, ChanTransport::new(kill.clone(), net.clone(), metrics.clone()));
    // the "standby" is the same server reached directly: promotion swaps
    // transports, which is all the trace needs to observe
    view.register_standby(0, 0, ChanTransport::new(s.clone(), net.clone(), metrics.clone()));
    let agent = BAgent::new(1, view, metrics.clone());
    s.register_pusher(1, ChanNotify::new(agent.clone(), net));

    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/pre", b"x").unwrap();
    kill.dead.store(true, Ordering::Release);
    p.mkdir("/after", 0o755).unwrap();
    assert!(metrics.failovers() >= 1, "the dead primary must have been failed over");

    let failed_attempt = agent
        .tracer()
        .snapshot()
        .into_iter()
        .find(|s| s.note.contains("failover"))
        .expect("the failed attempt must be annotated");
    let spans = whole_trace(&agent, &[&s], failed_attempt.trace_id);
    assert_single_tree(&spans);
    assert!(
        spans.iter().any(|sp| sp.server),
        "the promoted retry must land a server span in the SAME trace: {spans:?}"
    );
}

// ---------------------------------------------------------------------------
// Legacy interop
// ---------------------------------------------------------------------------

/// Wraps a real BServer but answers the `Traced` envelope the way a
/// pre-telemetry binary's decoder would: protocol error on tag 42.
struct LegacyServer {
    inner: Arc<BServer>,
    traced_seen: AtomicU64,
}

impl Service for LegacyServer {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::Traced { .. } => {
                self.traced_seen.fetch_add(1, Ordering::Relaxed);
                Response::Err(FsError::Protocol("bad request tag 42".into()))
            }
            other => self.inner.handle(other),
        }
    }
}

#[test]
fn legacy_peer_sticky_downgrades_tracing_without_erroring() {
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let legacy = Arc::new(LegacyServer { inner: s.clone(), traced_seen: AtomicU64::new(0) });
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let view = ClusterView::new(s.fs.root_ino());
    view.add(0, 0, ChanTransport::new(legacy.clone(), net.clone(), metrics.clone()));
    let agent = BAgent::new(1, view, metrics);
    s.register_pusher(1, ChanNotify::new(agent.clone(), net));

    assert!(agent.tracing_enabled());
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.put("/t", b"payload").unwrap();
    assert_eq!(p.get("/t", 64).unwrap(), b"payload");

    assert!(!agent.tracing_enabled(), "the rejection must stick");
    assert_eq!(agent.stats.trace_downgrades.load(Ordering::Relaxed), 1);
    let seen = legacy.traced_seen.load(Ordering::Relaxed);
    assert_eq!(seen, 1, "exactly one envelope probed the peer");
    assert!(
        agent.tracer().snapshot().iter().any(|sp| sp.note.contains("trace_downgrade")),
        "the probe attempt must be annotated"
    );

    // downgraded for good: later ops never re-send the envelope
    p.put("/t2", b"more").unwrap();
    assert_eq!(p.get("/t2", 64).unwrap(), b"more");
    assert_eq!(legacy.traced_seen.load(Ordering::Relaxed), seen);
}

// ---------------------------------------------------------------------------
// Slow-op log vs ring overwrite, remote drain
// ---------------------------------------------------------------------------

#[test]
fn ring_overwrite_keeps_slow_ops_and_sec_slow_drains_them_remotely() {
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    s.obs.trace.set_slow_threshold_us(100);
    let slow = Span {
        trace_id: 1,
        span_id: 777,
        parent: 0,
        name: "slow-op".into(),
        note: String::new(),
        host: 0,
        server: true,
        start_us: 1,
        dur_us: 5000,
    };
    s.obs.trace.record(slow);
    for i in 0..(RING_CAP + 64) as u64 {
        s.obs.trace.record(Span {
            trace_id: 2,
            span_id: 1000 + i,
            parent: 0,
            name: "fast".into(),
            note: String::new(),
            host: 0,
            server: true,
            start_us: 2 + i,
            dur_us: 1,
        });
    }
    assert!(s.obs.trace.trace(1).is_empty(), "the flood must have evicted the slow span");
    assert_eq!(s.obs.trace.slow_len(), 1, "the slow log must have kept it");

    match s.handle(Request::StatsFetch { sections: SEC_SLOW, trace_id: 0 }) {
        Response::Stats { spans, .. } => {
            assert!(spans.iter().any(|sp| sp.span_id == 777), "SEC_SLOW must return it");
        }
        other => panic!("stats fetch returned {other:?}"),
    }
    match s.handle(Request::StatsFetch { sections: SEC_SLOW, trace_id: 0 }) {
        Response::Stats { spans, .. } => {
            assert!(spans.is_empty(), "SEC_SLOW drains: a second fetch must come up empty");
        }
        other => panic!("stats fetch returned {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Snapshot reconciliation
// ---------------------------------------------------------------------------

#[test]
fn statsfetch_snapshot_reconciles_with_client_rpc_metrics() {
    let cluster = fast_cluster(1);
    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/w", 0o755).unwrap();
    for i in 0..3 {
        p.put(&format!("/w/f{i}"), format!("body {i}").as_bytes()).unwrap();
    }
    assert_eq!(p.get("/w/f0", 64).unwrap(), b"body 0");
    p.readdir("/w").unwrap();
    p.stat("/w/f1").unwrap();
    quiesce(&metrics);

    let s = cluster.server(0).unwrap();
    // wait for the last async closes to be dispatched server-side too
    for _ in 0..200 {
        if s.obs.dispatch_total() == metrics.total_rpcs() {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(
        s.obs.dispatch_total(),
        metrics.total_rpcs(),
        "every client RPC dispatches exactly once (Traced envelopes are never double-counted)"
    );
    for op in OPS {
        assert_eq!(
            s.obs.dispatch_count(op),
            metrics.count(op),
            "per-op reconciliation failed for {op}"
        );
    }

    let expected_creates = s.obs.dispatch_count("create");
    assert!(expected_creates >= 3);
    match s.handle(Request::StatsFetch { sections: SEC_OPS | SEC_SERVER, trace_id: 0 }) {
        Response::Stats { json, spans } => {
            assert!(spans.is_empty(), "no span sections requested");
            assert!(json.contains("\"host\":0"), "got {json}");
            assert!(
                json.contains(&format!("\"create\":{{\"n\":{expected_creates}")),
                "ops section must carry the true create count: {json}"
            );
            assert!(json.contains("\"server\":{"), "got {json}");
            assert!(json.contains("\"admission\":{\"sheds\":0}"), "got {json}");
            assert!(!json.contains("\"replicate\""), "never-dispatched ops must be omitted: {json}");
        }
        other => panic!("stats fetch returned {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Storm acceptance
// ---------------------------------------------------------------------------

#[test]
fn open_storm_traces_stay_single_linked_trees() {
    let cluster = fast_cluster(1);
    let admin = {
        let (agent, _) = cluster.make_agent();
        Buffet::process(agent, Credentials::root())
    };
    admin.mkdir("/s", 0o755).unwrap();
    for i in 0..32 {
        admin.put(&format!("/s/f{i}"), format!("body {i}").as_bytes()).unwrap();
    }

    let (agent, _metrics) = cluster.make_agent();
    std::thread::scope(|scope| {
        for w in 0..4u32 {
            let agent = agent.clone();
            scope.spawn(move || {
                let p = Buffet::with_pid(agent, 100 + w, Credentials::root());
                for i in (w * 8)..(w * 8 + 8) {
                    let fd = p.open(&format!("/s/f{i}"), OpenFlags::RDONLY).unwrap();
                    assert_eq!(p.read(fd, 64).unwrap(), format!("body {i}").into_bytes());
                    p.close(fd).unwrap();
                }
            });
        }
    });

    let server = cluster.server(0).unwrap();
    let roots: Vec<Span> = agent
        .tracer()
        .snapshot()
        .into_iter()
        .filter(|s| s.name == "open" && s.parent == 0)
        .collect();
    assert!(roots.len() >= 32, "every open records a root span, got {}", roots.len());
    let mut with_server_half = 0;
    for root in &roots {
        let spans = whole_trace(&agent, &[&server], root.trace_id);
        assert_single_tree(&spans);
        if spans.iter().any(|s| s.server) {
            with_server_half += 1;
        }
    }
    assert!(
        with_server_half >= 1,
        "cold opens under the storm must link client and server halves"
    );
}
