//! Sharded-cache concurrency: many reader threads hammering `open()` on a
//! shared BAgent — warm (must stay RPC-free and lock-free) and under a
//! concurrent §3.4 invalidation storm (must stay correct).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};

const N_FILES: usize = 32;
const N_THREADS: usize = 8;
const OPENS_PER_THREAD: usize = 200;

fn fast_cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 3 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

fn quiesce(metrics: &buffetfs::metrics::RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

#[test]
fn warm_open_storm_is_rpc_free_across_threads() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/s", 0o755).unwrap();
    for i in 0..N_FILES {
        admin.put(&format!("/s/f{i}"), b"data").unwrap();
    }
    admin.readdir("/s").unwrap(); // warm the whole listing
    quiesce(&metrics);

    let before = metrics.total_rpcs();
    let ok = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for t in 0..N_THREADS {
            let agent = agent.clone();
            let ok = &ok;
            scope.spawn(move || {
                let pid = 9000 + t as u32;
                let cred = Credentials::root();
                for i in 0..OPENS_PER_THREAD {
                    let path = format!("/s/f{}", (i * 7 + t) % N_FILES);
                    let fd = agent.open(pid, &path, OpenFlags::RDONLY, &cred).unwrap();
                    agent.close(pid, fd).unwrap();
                    ok.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
    });
    assert_eq!(ok.load(Ordering::Relaxed), (N_THREADS * OPENS_PER_THREAD) as u64);
    assert_eq!(
        metrics.total_rpcs(),
        before,
        "8 warm reader threads must complete the storm without a single RPC"
    );
    assert!(
        agent.stats.rpc_free_opens.load(Ordering::Relaxed)
            >= (N_THREADS * OPENS_PER_THREAD) as u64
    );
}

#[test]
fn open_storm_survives_concurrent_invalidation_pushes() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/v", 0o755).unwrap();
    for i in 0..N_FILES {
        admin.put(&format!("/v/f{i}"), b"data").unwrap();
    }
    admin.readdir("/v").unwrap();
    quiesce(&metrics);

    let ok = AtomicU64::new(0);
    let busy = AtomicU64::new(0);
    let stop = std::sync::atomic::AtomicBool::new(false);
    std::thread::scope(|scope| {
        // the chmod storm: every flip runs the §3.4 invalidate-then-apply
        // barrier against this very agent's cache
        {
            let admin = Buffet::process(agent.clone(), Credentials::root());
            let stop = &stop;
            scope.spawn(move || {
                let mut mode = 0o640;
                while !stop.load(Ordering::Relaxed) {
                    match admin.chmod("/v/f0", mode) {
                        // its own resolve can lose the refetch race too
                        Ok(()) | Err(FsError::Busy) => {}
                        Err(e) => panic!("chmod storm failed: {e}"),
                    }
                    mode = if mode == 0o640 { 0o644 } else { 0o640 };
                    std::thread::sleep(Duration::from_micros(50));
                }
            });
        }
        for t in 0..N_THREADS {
            let agent = agent.clone();
            let (ok, busy) = (&ok, &busy);
            scope.spawn(move || {
                let pid = 9100 + t as u32;
                let cred = Credentials::root();
                for i in 0..OPENS_PER_THREAD {
                    let path = format!("/v/f{}", (i * 5 + t) % N_FILES);
                    match agent.open(pid, &path, OpenFlags::RDONLY, &cred) {
                        Ok(fd) => {
                            agent.close(pid, fd).unwrap();
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        // a sustained invalidation race may exhaust the
                        // bounded refetch retries — acceptable, never wrong
                        Err(FsError::Busy) => {
                            busy.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("open under invalidation storm failed: {e}"),
                    }
                }
            });
        }
        // readers finish first (scope joins all spawned threads in drop
        // order is unspecified, so stop the chmod loop explicitly once
        // every reader thread has pushed its quota)
        while ok.load(Ordering::Relaxed) + busy.load(Ordering::Relaxed)
            < (N_THREADS * OPENS_PER_THREAD) as u64
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        stop.store(true, Ordering::Relaxed);
    });
    let done = ok.load(Ordering::Relaxed);
    assert!(
        done >= (N_THREADS * OPENS_PER_THREAD) as u64 * 9 / 10,
        "at least 90% of opens must succeed under the storm (ok={done}, busy={})",
        busy.load(Ordering::Relaxed)
    );
    assert!(
        agent.stats.invalidations_rx.load(Ordering::Relaxed) > 0,
        "the storm must actually have pushed invalidations at this agent"
    );
    // after the dust settles the cache must converge back to RPC-free
    quiesce(&metrics);
    let p = Buffet::process(agent.clone(), Credentials::root());
    let fd = p.open("/v/f1", OpenFlags::RDONLY).unwrap();
    p.close(fd).unwrap();
    let before = metrics.total_rpcs();
    let fd = p.open("/v/f1", OpenFlags::RDONLY).unwrap();
    p.close(fd).unwrap();
    assert_eq!(metrics.total_rpcs(), before, "cache converges to warm after the storm");
}
