//! The pipelined multiplexed RPC engine end-to-end (DESIGN.md §9):
//!
//! * out-of-order completion over one connection — a slow `ReadBatch`
//!   must not head-of-line-block a tiny `GetAttr` (chan and TCP);
//! * the acceptance storm: depth-8 pipelined small-file opens over ONE
//!   simnet connection are ≥ 4× faster than lockstep;
//! * downgrade interop: a pipelined client against a legacy lockstep
//!   server (and a legacy client against a new server) both work
//!   unchanged;
//! * a multi-threaded pipelined storm over one shared TCP connection
//!   routes every response to the right waiter;
//! * bounded admission: past the per-connection hard cap the server
//!   sheds with `Busy` instead of queueing unboundedly, and recovers;
//! * the datapath fan-out (`pipeline_ways`) preserves bytes exactly.

use std::io::{Read, Write as IoWrite};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::codec::Wire;
use buffetfs::datapath::DatapathConfig;
use buffetfs::error::FsError;
use buffetfs::metrics::RpcMetrics;
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::ChanTransport;
use buffetfs::transport::tcp::{TcpServer, TcpTransport};
use buffetfs::transport::{wait_all, Service, Transport};
use buffetfs::types::{Credentials, FileKind, Ino, OpenFlags};
use buffetfs::wire::{ByteRange, Request, Response, NO_GEN};

fn server() -> Arc<BServer> {
    BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())))
}

fn root() -> Ino {
    Ino::new(0, 0, 1)
}

fn cred() -> Credentials {
    Credentials::root()
}

fn create_file(s: &Arc<BServer>, name: &str, content: &[u8]) -> Ino {
    let e = match s.handle(Request::Create {
        dir: root(),
        name: name.into(),
        mode: 0o644,
        kind: FileKind::Regular,
        cred: cred(),
        client: 0,
    }) {
        Response::Created(e) => e,
        other => panic!("create: {other:?}"),
    };
    if !content.is_empty() {
        s.handle(Request::Write { ino: e.ino, off: 0, data: content.to_vec(), open_ctx: None });
    }
    e.ino
}

/// A service that handles `ReadBatch` slowly and everything else via the
/// real server — the head-of-line-blocking probe.
struct SlowReads {
    inner: Arc<BServer>,
    delay: Duration,
}

impl Service for SlowReads {
    fn handle(&self, req: Request) -> Response {
        if matches!(req, Request::ReadBatch { .. }) {
            std::thread::sleep(self.delay);
        }
        self.inner.handle(req)
    }
}

// ---------------------------------------------------------------------------
// Out-of-order completion + fairness
// ---------------------------------------------------------------------------

#[test]
fn slow_readbatch_does_not_block_stat_over_chan() {
    let s = server();
    let ino = create_file(&s, "big.dat", &[1u8; 4096]);
    let svc = Arc::new(SlowReads { inner: s, delay: Duration::from_millis(300) });
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let t = ChanTransport::new(svc, net, metrics.clone());

    let slow = t
        .submit(Request::ReadBatch {
            ino,
            ranges: vec![ByteRange { off: 0, len: 4096 }],
            known_gen: NO_GEN,
            client: 1,
            register: false,
            open_ctx: None,
        })
        .unwrap();
    let fast = t.submit(Request::GetAttr { ino }).unwrap();
    let t0 = Instant::now();
    let r = t.wait(fast).unwrap();
    assert!(matches!(r, Response::AttrR(_)));
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "a 1-attr stat waited {:?} behind a slow ReadBatch",
        t0.elapsed()
    );
    assert!(matches!(t.wait(slow).unwrap(), Response::DataBatch { .. }));
    assert!(metrics.ooo_completions() >= 1, "the stat overtook: must count as out-of-order");
}

#[test]
fn slow_readbatch_does_not_block_stat_over_tcp() {
    let s = server();
    let ino = create_file(&s, "big.dat", &[2u8; 4096]);
    let svc = Arc::new(SlowReads { inner: s, delay: Duration::from_millis(300) });
    let tcp = TcpServer::spawn("127.0.0.1:0", svc).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect_pipelined(tcp.local_addr, metrics.clone()).unwrap();
    assert!(t.is_pipelined_mode(), "new server must accept the handshake");

    let slow = t
        .submit(Request::ReadBatch {
            ino,
            ranges: vec![ByteRange { off: 0, len: 4096 }],
            known_gen: NO_GEN,
            client: 1,
            register: false,
            open_ctx: None,
        })
        .unwrap();
    let fast = t.submit(Request::GetAttr { ino }).unwrap();
    let t0 = Instant::now();
    assert!(matches!(t.wait(fast).unwrap(), Response::AttrR(_)));
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "stat head-of-line-blocked over TCP: {:?}",
        t0.elapsed()
    );
    assert!(matches!(t.wait(slow).unwrap(), Response::DataBatch { .. }));
    assert!(metrics.ooo_completions() >= 1);
    assert_eq!(tcp.stats.pipelined_conns.load(Ordering::Relaxed), 1);
    tcp.shutdown();
}

// ---------------------------------------------------------------------------
// The acceptance storm (chan, one connection)
// ---------------------------------------------------------------------------

#[test]
fn depth8_pipelined_storm_is_4x_faster_than_lockstep() {
    let s = server();
    let inos: Vec<Ino> =
        (0..8).map(|i| create_file(&s, &format!("f{i}"), &[i as u8; 1024])).collect();
    let metrics = Arc::new(RpcMetrics::new());
    let cfg = NetConfig { one_way_us: 2000, per_kb_us: 0, jitter_us: 0, seed: 3 };
    let t = ChanTransport::new(s, Arc::new(LatencyModel::new(cfg)), metrics);
    t.set_pipeline_depth(8);
    let open = |ino: Ino, handle: u64| Request::Open {
        ino,
        flags: OpenFlags::RDONLY,
        cred: cred(),
        client: 1,
        handle,
        want_inline: true,
    };

    let t0 = Instant::now();
    for (i, ino) in inos.iter().enumerate() {
        t.call(open(*ino, 100 + i as u64)).unwrap();
    }
    let lockstep = t0.elapsed();

    let t0 = Instant::now();
    let pending: Vec<_> = inos
        .iter()
        .enumerate()
        .map(|(i, ino)| t.submit(open(*ino, 200 + i as u64)).unwrap())
        .collect();
    for r in wait_all(t.as_ref(), pending) {
        assert!(matches!(r.unwrap(), Response::OpenedInline { .. }));
    }
    let pipelined = t0.elapsed();
    assert!(
        pipelined * 4 <= lockstep,
        "acceptance: ≥ 4× at depth 8 — lockstep={lockstep:?} pipelined={pipelined:?}"
    );
}

// ---------------------------------------------------------------------------
// Downgrade interop
// ---------------------------------------------------------------------------

/// A true legacy lockstep server: bare length-prefixed wire frames, no
/// mux header understanding, strictly one request at a time — what every
/// pre-engine peer speaks.
fn spawn_legacy_server(s: Arc<BServer>) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let h = std::thread::spawn(move || {
        let Ok((mut conn, _)) = listener.accept() else { return };
        loop {
            let mut len = [0u8; 4];
            if conn.read_exact(&mut len).is_err() {
                return;
            }
            let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
            if conn.read_exact(&mut buf).is_err() {
                return;
            }
            let resp = match Request::from_bytes(&buf) {
                Ok(req) => s.handle(req),
                Err(e) => Response::Err(e),
            };
            let payload = resp.to_bytes();
            if conn.write_all(&(payload.len() as u32).to_le_bytes()).is_err()
                || conn.write_all(&payload).is_err()
            {
                return;
            }
        }
    });
    (addr, h)
}

#[test]
fn pipelined_client_sticky_downgrades_against_legacy_server() {
    let s = server();
    let ino = create_file(&s, "old.dat", b"legacy bytes");
    let (addr, srv) = spawn_legacy_server(s);
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect_pipelined(addr, metrics.clone()).unwrap();
    assert!(!t.is_pipelined_mode(), "legacy peer must trigger the sticky downgrade");
    // everything still works over the lockstep schedule
    match t.call(Request::Read { ino, off: 0, len: 64, open_ctx: None }).unwrap() {
        Response::Data { data, .. } => assert_eq!(data, b"legacy bytes"),
        other => panic!("{other:?}"),
    }
    // submit/wait degrade to deferred calls — same results, zero submits
    let p = t.submit(Request::GetAttr { ino }).unwrap();
    assert!(matches!(t.wait(p).unwrap(), Response::AttrR(_)));
    assert_eq!(metrics.pipelined_submits(), 0, "downgraded connection never muxes");
    drop(t);
    let _ = srv; // server thread exits when the connection drops
}

#[test]
fn legacy_client_works_against_new_server() {
    let s = server();
    let ino = create_file(&s, "new.dat", b"hello");
    let tcp = TcpServer::spawn("127.0.0.1:0", s).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    // plain connect: no handshake, bare legacy frames
    let t = TcpTransport::connect(tcp.local_addr, metrics).unwrap();
    assert!(!t.is_pipelined_mode());
    match t.call(Request::Read { ino, off: 0, len: 64, open_ctx: None }).unwrap() {
        Response::Data { data, .. } => assert_eq!(data, b"hello"),
        other => panic!("{other:?}"),
    }
    assert_eq!(tcp.stats.legacy_conns.load(Ordering::Relaxed), 1);
    assert_eq!(tcp.stats.pipelined_conns.load(Ordering::Relaxed), 0);
    tcp.shutdown();
}

#[test]
fn pipelined_full_cycle_over_tcp() {
    let s = server();
    let tcp = TcpServer::spawn("127.0.0.1:0", s).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect_pipelined(tcp.local_addr, metrics.clone()).unwrap();
    assert!(t.is_pipelined_mode());
    let ino = match t
        .call(Request::Create {
            dir: root(),
            name: "cycle.dat".into(),
            mode: 0o644,
            kind: FileKind::Regular,
            cred: cred(),
            client: 1,
        })
        .unwrap()
    {
        Response::Created(e) => e.ino,
        other => panic!("{other:?}"),
    };
    t.call(Request::Write { ino, off: 0, data: b"over the mux".to_vec(), open_ctx: None })
        .unwrap();
    match t.call(Request::Read { ino, off: 5, len: 32, open_ctx: None }).unwrap() {
        Response::Data { data, .. } => assert_eq!(data, b"he mux"),
        other => panic!("{other:?}"),
    }
    // the asynchronous close wrap-up rides the engine as fire-and-forget
    t.call_async(Request::Close { ino, client: 1, handle: 9 }).unwrap();
    // it completes without anyone waiting (metrics record it)
    for _ in 0..100 {
        if metrics.count("close") == 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(metrics.count("close"), 1, "fire-and-forget close must complete");
    tcp.shutdown();
}

// ---------------------------------------------------------------------------
// Multi-threaded storm over one shared connection
// ---------------------------------------------------------------------------

#[test]
fn multithreaded_storm_routes_every_response_to_its_waiter() {
    let s = server();
    // 8 threads × 8 files, each file holds its owner's distinct pattern
    let inos: Vec<Vec<Ino>> = (0..8u8)
        .map(|w| {
            (0..8u8)
                .map(|i| create_file(&s, &format!("w{w}f{i}"), &[w * 16 + i; 512]))
                .collect()
        })
        .collect();
    let tcp = TcpServer::spawn("127.0.0.1:0", s).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect_pipelined_with(
        tcp.local_addr,
        Some(Duration::from_secs(30)),
        64,
        metrics.clone(),
    )
    .unwrap();
    assert!(t.is_pipelined_mode());
    std::thread::scope(|scope| {
        for (w, files) in inos.iter().enumerate() {
            let t = &t;
            scope.spawn(move || {
                for round in 0..5 {
                    let pending: Vec<_> = files
                        .iter()
                        .map(|ino| {
                            t.submit(Request::ReadBatch {
                                ino: *ino,
                                ranges: vec![ByteRange { off: 0, len: 512 }],
                                known_gen: NO_GEN,
                                client: w as u32,
                                register: false,
                                open_ctx: None,
                            })
                            .unwrap()
                        })
                        .collect();
                    for (i, r) in wait_all(t.as_ref(), pending).into_iter().enumerate() {
                        match r.unwrap() {
                            Response::DataBatch { segs, .. } => {
                                let want = vec![w as u8 * 16 + i as u8; 512];
                                assert_eq!(
                                    segs[0], want,
                                    "thread {w} round {round} got bytes routed to the wrong waiter"
                                );
                            }
                            other => panic!("{other:?}"),
                        }
                    }
                }
            });
        }
    });
    assert_eq!(metrics.count("read"), 8 * 8 * 5);
    assert!(metrics.pipelined_submits() >= 8 * 8 * 5);
    tcp.shutdown();
}

// ---------------------------------------------------------------------------
// Bounded admission (Busy shed) — satellite regression test
// ---------------------------------------------------------------------------

#[test]
fn admission_sheds_busy_past_hard_cap_and_recovers() {
    use buffetfs::transport::tcp::PIPE_ADMIT_CAP;
    let s = server();
    let ino = create_file(&s, "slow.dat", &[1u8; 64]);
    struct SlowAll {
        inner: Arc<BServer>,
    }
    impl Service for SlowAll {
        fn handle(&self, req: Request) -> Response {
            if matches!(req, Request::GetAttr { .. }) {
                std::thread::sleep(Duration::from_millis(50));
            }
            self.inner.handle(req)
        }
    }
    let tcp = TcpServer::spawn("127.0.0.1:0", Arc::new(SlowAll { inner: s })).unwrap();
    let metrics = Arc::new(RpcMetrics::new());
    // client-side depth far above the server's hard cap, so the storm
    // really lands on the server
    let storm = PIPE_ADMIT_CAP + 150;
    let t = TcpTransport::connect_pipelined_with(
        tcp.local_addr,
        Some(Duration::from_secs(60)),
        storm + 16,
        metrics,
    )
    .unwrap();
    assert!(t.is_pipelined_mode());
    let pending: Vec<_> =
        (0..storm).map(|_| t.submit(Request::GetAttr { ino }).unwrap()).collect();
    let (mut ok, mut busy) = (0usize, 0usize);
    for r in wait_all(t.as_ref(), pending) {
        match r {
            Ok(Response::AttrR(_)) => ok += 1,
            Err(FsError::Busy) => busy += 1,
            other => panic!("unexpected storm result: {other:?}"),
        }
    }
    assert!(busy > 0, "a {storm}-deep storm must shed past the {PIPE_ADMIT_CAP} cap");
    assert!(ok >= PIPE_ADMIT_CAP - 8, "admitted requests must all be served, got {ok}");
    assert_eq!(tcp.stats.shed_busy.load(Ordering::Relaxed), busy as u64);
    // the connection survived the storm: normal traffic flows again
    assert!(matches!(t.call(Request::GetAttr { ino }).unwrap(), Response::AttrR(_)));
    tcp.shutdown();
}

// ---------------------------------------------------------------------------
// Datapath fan-out (pipeline_ways)
// ---------------------------------------------------------------------------

fn pattern(n: usize) -> Vec<u8> {
    (0..n).map(|i| (i * 37 % 253) as u8).collect()
}

#[test]
fn datapath_fanout_scan_and_flush_preserve_bytes() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 23 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (setup, _) = cluster.make_agent();
    let admin = Buffet::process(setup, Credentials::root());
    admin.mkdir("/p", 0o777).unwrap();
    let size = 1 << 20;
    let content = pattern(size);
    admin.put("/p/big.bin", &content).unwrap();

    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig {
        inline_limit: 0, // force the ReadBatch path
        pipeline_ways: 4,
        ..DatapathConfig::default()
    });
    let p = Buffet::process(agent.clone(), Credentials::new(1000, 1000));

    // overlapping-window scan: bytes must be exact
    let fd = p.open("/p/big.bin", OpenFlags::RDONLY).unwrap();
    let mut got = Vec::with_capacity(size);
    loop {
        let chunk = p.read(fd, 8192).unwrap();
        if chunk.is_empty() {
            break;
        }
        got.extend_from_slice(&chunk);
    }
    p.close(fd).unwrap();
    assert_eq!(got, content, "4-way fan-out scan must reassemble exactly");
    assert!(metrics.pipelined_submits() > 0, "the scan must actually use submit/wait_all");

    // pipelined flush: disjoint extents, one close, exact bytes
    let fd = p.open("/p/out.bin", OpenFlags::RDWR.with_create()).unwrap();
    for i in 0..64u64 {
        // stride leaves holes → many disjoint extents → multi-way flush
        p.pwrite(fd, i * 1000, &[i as u8; 100]).unwrap();
    }
    let before = metrics.pipelined_submits();
    p.close(fd).unwrap();
    assert!(metrics.pipelined_submits() > before, "the flush must pipeline its batches");
    let fd = p.open("/p/out.bin", OpenFlags::RDONLY).unwrap();
    for i in [0u64, 13, 63] {
        let seg = p.pread(fd, i * 1000, 100).unwrap();
        assert_eq!(seg, vec![i as u8; 100], "extent {i} corrupted by the pipelined flush");
    }
    let hole = p.pread(fd, 100, 100).unwrap();
    assert_eq!(hole, vec![0u8; 100], "holes between extents must stay zero");
    p.close(fd).unwrap();
}
