//! Property-style randomized invariants (seeded, reproducible — the
//! offline stand-in for proptest):
//!
//! 1. **Model equivalence** — a random op sequence through the full
//!    BuffetFS stack must agree byte-for-byte with a flat in-memory
//!    model (HashMap of path → contents).
//! 2. **Cache transparency** — every read served through a warm agent
//!    cache equals a read through a brand-new (cold) agent.
//! 3. **Permission equivalence** — BuffetFS's client-side verdict equals
//!    the Lustre baseline's server-side verdict on identical trees.

use std::collections::HashMap;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::types::{Credentials, OpenFlags};
use buffetfs::util::rng::XorShift;

fn cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(2, NetConfig::zero(), Backing::Mem, false, ServiceConfig::unbounded())
}

#[derive(Debug)]
enum Op {
    Put(usize, Vec<u8>),
    Append(usize, Vec<u8>),
    Truncate(usize, u64),
    Unlink(usize),
    Read(usize),
}

fn gen_ops(seed: u64, n: usize, files: usize) -> Vec<Op> {
    let mut r = XorShift::new(seed);
    (0..n)
        .map(|_| {
            let f = r.below(files as u64) as usize;
            match r.below(5) {
                0 => Op::Put(f, (0..r.below(200)).map(|_| r.next_u64() as u8).collect()),
                1 => Op::Append(f, (0..r.below(64)).map(|_| r.next_u64() as u8).collect()),
                2 => Op::Truncate(f, r.below(128)),
                3 => Op::Unlink(f),
                _ => Op::Read(f),
            }
        })
        .collect()
}

#[test]
fn random_op_sequences_match_flat_model() {
    for seed in [1u64, 2, 3, 4, 5] {
        let c = cluster();
        let (agent, _) = c.make_agent();
        let p = Buffet::process(agent, Credentials::root());
        p.mkdir("/m", 0o777).unwrap();
        let mut model: HashMap<usize, Vec<u8>> = HashMap::new();

        for (step, op) in gen_ops(seed, 300, 12).iter().enumerate() {
            let path = |f: &usize| format!("/m/file{f}");
            match op {
                Op::Put(f, data) => {
                    p.put(&path(f), data).unwrap();
                    model.insert(*f, data.clone());
                }
                Op::Append(f, data) => {
                    let fd = p.open(&path(f), OpenFlags::WRONLY.with_create().with_append()).unwrap();
                    p.write(fd, data).unwrap();
                    p.close(fd).unwrap();
                    model.entry(*f).or_default().extend_from_slice(data);
                }
                Op::Truncate(f, size) => {
                    if model.contains_key(f) {
                        p.truncate(&path(f), *size).unwrap();
                        let v = model.get_mut(f).unwrap();
                        v.resize(*size as usize, 0);
                    } else {
                        assert_eq!(p.truncate(&path(f), *size).unwrap_err(), FsError::NotFound);
                    }
                }
                Op::Unlink(f) => {
                    if model.remove(f).is_some() {
                        p.unlink(&path(f)).unwrap();
                    } else {
                        assert_eq!(p.unlink(&path(f)).unwrap_err(), FsError::NotFound);
                    }
                }
                Op::Read(f) => match model.get(f) {
                    Some(expect) => {
                        let got = p.get(&path(f), (expect.len() as u32).max(1)).unwrap();
                        assert_eq!(&got, expect, "seed {seed} step {step}: {op:?}");
                    }
                    None => {
                        assert_eq!(
                            p.open(&path(f), OpenFlags::RDONLY).unwrap_err(),
                            FsError::NotFound,
                            "seed {seed} step {step}"
                        );
                    }
                },
            }
        }
        // final sweep: model and fs agree on the survivors
        let listed: Vec<String> = p.readdir("/m").unwrap().into_iter().map(|e| e.name).collect();
        assert_eq!(listed.len(), model.len(), "seed {seed}: {listed:?}");
    }
}

#[test]
fn warm_cache_reads_equal_cold_client_reads() {
    let c = cluster();
    let (warm_agent, _) = c.make_agent();
    let warm = Buffet::process(warm_agent, Credentials::root());
    warm.mkdir("/eq", 0o777).unwrap();
    let mut r = XorShift::new(77);
    for i in 0..40 {
        let body: Vec<u8> = (0..r.range(1, 300)).map(|_| r.next_u64() as u8).collect();
        warm.put(&format!("/eq/f{i}"), &body).unwrap();
    }
    // warm agent has everything cached; a cold agent starts from scratch
    let (cold_agent, _) = c.make_agent();
    let cold = Buffet::process(cold_agent, Credentials::root());
    for i in 0..40 {
        let path = format!("/eq/f{i}");
        let a = warm.get(&path, 512).unwrap();
        let b = cold.get(&path, 512).unwrap();
        assert_eq!(a, b, "{path}");
    }
}

#[test]
fn client_side_verdicts_equal_server_side_verdicts() {
    use buffetfs::baseline::{LustreCluster, LustreMode};
    let mut r = XorShift::new(0xACCE55);
    for round in 0..5 {
        // identical tree on both systems: /t/dX/fY with random modes
        let bc = cluster();
        let lc = LustreCluster::spawn_with(
            1,
            LustreMode::Normal,
            NetConfig::zero(),
            Backing::Mem,
            ServiceConfig::unbounded(),
        );
        let (ba, _) = bc.make_agent();
        let buffet_admin = Buffet::process(ba.clone(), Credentials::root());
        let (lclient, _) = lc.make_client();
        let root = Credentials::root();

        let mut cases = Vec::new();
        for d in 0..3 {
            let dmode = 0o700 | (r.below(8) as u16) << 3 | r.below(8) as u16;
            buffet_admin.mkdir(&format!("/d{d}"), dmode).unwrap();
            lclient.mkdir(&format!("/d{d}"), dmode, &root).unwrap();
            for f in 0..6 {
                let fmode = (r.below(0o1000)) as u16;
                let path = format!("/d{d}/f{f}");
                buffet_admin.create(&path, fmode).unwrap();
                lclient.create(&path, fmode, &root).unwrap();
                cases.push(path);
            }
        }
        let cred = Credentials::with_groups(r.below(4) as u32 + 1, r.below(4) as u32, vec![]);
        let buffet_user = Buffet::process(ba.clone(), cred.clone());
        for path in &cases {
            let b = buffet_user.open(path, OpenFlags::RDONLY).map(|fd| {
                buffet_user.close(fd).ok();
            });
            let l = lclient.open(9, path, OpenFlags::RDONLY, &cred).map(|fd| {
                lclient.close(9, fd).ok();
            });
            assert_eq!(
                b.is_ok(),
                l.is_ok(),
                "round {round} {path}: buffet(client-side)={b:?} lustre(server-side)={l:?} cred={cred:?}"
            );
        }
    }
}
