//! Tentpole integration: the batched `ResolvePath` cold walk.
//!
//! Acceptance: a cold open of a depth-D path on a single-server namespace
//! issues exactly ONE RPC; crossing a server boundary costs one RPC per
//! server; the per-level fallback still works when batching is disabled.

use std::sync::atomic::Ordering;

use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster};
use buffetfs::error::FsError;
use buffetfs::simnet::NetConfig;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::Service;
use buffetfs::types::{Credentials, DirEntry, FileKind, Ino, OpenFlags, PermBlob};
use buffetfs::wire::{Request, Response};

fn fast_cluster(n: u16) -> BuffetCluster {
    BuffetCluster::spawn_with(
        n,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 1 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

/// Build /a/b/c/d/f.dat through an admin agent, then cold-open it through
/// a FRESH agent and count RPCs.
#[test]
fn cold_open_of_depth_d_path_is_one_rpc() {
    let cluster = fast_cluster(1);
    let admin = {
        let (agent, _) = cluster.make_agent();
        Buffet::process(agent, Credentials::root())
    };
    admin.mkdir("/a", 0o755).unwrap();
    admin.mkdir("/a/b", 0o755).unwrap();
    admin.mkdir("/a/b/c", 0o755).unwrap();
    admin.mkdir("/a/b/c/d", 0o755).unwrap();
    admin.put("/a/b/c/d/f.dat", b"payload").unwrap();

    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    let before = metrics.total_rpcs();
    let fd = p.open("/a/b/c/d/f.dat", OpenFlags::RDONLY).unwrap();
    assert_eq!(
        metrics.total_rpcs(),
        before + 1,
        "cold open of a depth-5 path must cost exactly ONE RPC"
    );
    assert_eq!(metrics.count("resolve"), 1, "and that RPC is the batched walk");
    assert_eq!(metrics.count("readdir"), 0, "no per-level ReadDir on the batched path");
    // the walk returned every directory on the way: root, a, b, c, d
    let wd = metrics.walk_depth_histogram();
    assert_eq!(wd.count(), 1);
    assert_eq!(wd.max(), 5, "five listings shipped in the one response");
    assert_eq!(agent.stats.batch_walks.load(Ordering::Relaxed), 1);

    // the read carries the deferred open (unchanged §3.3 behaviour)
    assert_eq!(p.read(fd, 7).unwrap(), b"payload");
    assert_eq!(metrics.total_rpcs(), before + 2);
    p.close(fd).unwrap();

    // every directory of the walk is now cached: sibling and cousin opens
    // are RPC-free
    let before = metrics.total_rpcs();
    for path in ["/a/b/c/d/f.dat", "/a/b/c/d/f.dat"] {
        let fd = p.open(path, OpenFlags::RDONLY).unwrap();
        p.close(fd).unwrap();
    }
    assert_eq!(metrics.total_rpcs(), before, "warm opens stay RPC-free");
}

#[test]
fn walk_crosses_server_boundary_with_one_rpc_per_server() {
    let cluster = fast_cluster(2);
    let s0 = &cluster.servers[0];
    let s1 = &cluster.servers[1];

    // fabricate a decentralized layout: directory "m" lives on host 1,
    // its dirent on host 0's root (what CreateOrphan does for files)
    let m = s1
        .fs
        .create_orphan(cluster.root(), "m", 0o755, FileKind::Directory, 0, 0)
        .unwrap();
    s0.fs
        .insert_remote_entry(cluster.root().file, m.clone())
        .unwrap();
    match s1.handle(Request::Create {
        dir: m.ino,
        name: "x.dat".into(),
        mode: 0o644,
        kind: FileKind::Regular,
        cred: Credentials::root(),
        client: 0,
    }) {
        Response::Created(_) => {}
        other => panic!("create on host 1: {other:?}"),
    }

    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    let before = metrics.total_rpcs();
    let fd = p.open("/m/x.dat", OpenFlags::RDONLY).unwrap();
    assert_eq!(
        metrics.total_rpcs(),
        before + 2,
        "two servers on the path → exactly two batched-walk RPCs"
    );
    assert_eq!(metrics.count("resolve"), 2);
    p.close(fd).unwrap();

    // warm now on BOTH servers' directories
    let before = metrics.total_rpcs();
    let fd = p.open("/m/x.dat", OpenFlags::RDONLY).unwrap();
    assert_eq!(metrics.total_rpcs(), before);
    p.close(fd).unwrap();
}

#[test]
fn per_level_fallback_still_resolves_when_batching_disabled() {
    let cluster = fast_cluster(1);
    let admin = {
        let (agent, _) = cluster.make_agent();
        Buffet::process(agent, Credentials::root())
    };
    admin.mkdir("/p", 0o755).unwrap();
    admin.mkdir("/p/q", 0o755).unwrap();
    admin.put("/p/q/f", b"z").unwrap();

    let (agent, metrics) = cluster.make_agent();
    agent.set_batched_resolve(false);
    let p = Buffet::process(agent, Credentials::root());
    let fd = p.open("/p/q/f", OpenFlags::RDONLY).unwrap();
    assert_eq!(metrics.count("resolve"), 0, "batching disabled → no ResolvePath");
    assert_eq!(metrics.count("readdir"), 3, "per-level walk: root, /p, /p/q");
    assert_eq!(p.read(fd, 1).unwrap(), b"z");
    p.close(fd).unwrap();
}

#[test]
fn negative_entries_are_served_locally_with_stats() {
    let cluster = fast_cluster(1);
    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.mkdir("/neg", 0o755).unwrap();
    p.put("/neg/real", b"x").unwrap();
    p.readdir("/neg").unwrap(); // cache the listing

    let before_rpcs = metrics.total_rpcs();
    let (_, _, _, _, neg_before) = agent.cache().stats.snapshot();
    for _ in 0..3 {
        assert_eq!(p.open("/neg/ghost", OpenFlags::RDONLY).unwrap_err(), FsError::NotFound);
    }
    assert_eq!(metrics.total_rpcs(), before_rpcs, "cached ENOENT must cost zero RPCs");
    let (_, _, _, _, neg_after) = agent.cache().stats.snapshot();
    assert!(
        neg_after >= neg_before + 3,
        "each local ENOENT must be counted as a negative hit ({neg_before} → {neg_after})"
    );
}

#[test]
fn x_only_dirs_still_fall_back_to_lookup_rpcs() {
    let cluster = fast_cluster(1);
    let (agent, _) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/locked", 0o711).unwrap();
    admin.put("/locked/known", b"k").unwrap();
    admin.chmod("/locked/known", 0o644).unwrap();

    let user = Buffet::process(agent.clone(), Credentials::new(77, 77));
    assert_eq!(user.get("/locked/known", 1).unwrap(), b"k");
    assert!(agent.stats.fallback_lookups.load(Ordering::Relaxed) >= 1);
}

/// An old server that rejects ResolvePath downgrades the agent to the
/// per-level protocol instead of failing the open.
#[test]
fn protocol_rejection_downgrades_to_per_level() {
    use buffetfs::metrics::RpcMetrics;
    use buffetfs::server::BServer;
    use buffetfs::store::data::MemData;
    use buffetfs::store::fs::LocalFs;
    use buffetfs::transport::chan::{ChanNotify, ChanTransport};
    use buffetfs::cluster::ClusterView;
    use buffetfs::simnet::LatencyModel;
    use std::sync::Arc;

    /// Wraps a real BServer but answers ResolvePath the way a pre-batching
    /// binary would: protocol error.
    struct OldServer(Arc<BServer>);
    impl Service for OldServer {
        fn handle(&self, req: Request) -> Response {
            match req {
                Request::ResolvePath { .. } => {
                    Response::Err(FsError::Protocol("bad request tag 22".into()))
                }
                other => self.0.handle(other),
            }
        }
    }

    let server = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let root = server.fs.root_ino();
    server
        .handle(Request::Mkdir { dir: root, name: "d".into(), mode: 0o755, cred: Credentials::root() });
    server.handle(Request::Create {
        dir: root,
        name: "top".into(),
        mode: 0o644,
        kind: FileKind::Regular,
        cred: Credentials::root(),
        client: 0,
    });

    let old = Arc::new(OldServer(server.clone()));
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let view = ClusterView::new(root);
    view.add(0, 0, ChanTransport::new(old, net.clone(), metrics.clone()));
    let agent = buffetfs::agent::BAgent::new(1, view, metrics.clone());
    server.register_pusher(1, ChanNotify::new(agent.clone(), net));

    let p = Buffet::process(agent.clone(), Credentials::root());
    let fd = p.open("/top", OpenFlags::RDONLY).unwrap();
    p.close(fd).unwrap();
    assert!(
        agent.stats.resolve_downgrades.load(Ordering::Relaxed) >= 1,
        "the protocol rejection must be recorded as a downgrade"
    );
    assert!(metrics.count("readdir") >= 1, "resolution completed over per-level ReadDir");

    // the downgrade is sticky: no further ResolvePath attempts
    let resolves_after_downgrade = metrics.count("resolve");
    let fd = p.open("/top", OpenFlags::RDONLY).unwrap();
    p.close(fd).unwrap();
    assert_eq!(metrics.count("resolve"), resolves_after_downgrade);
}

/// The continuation token path, unit-style: exercised against the wire
/// messages to pin the response shape other implementations must honour.
#[test]
fn walked_response_roundtrips_on_the_wire() {
    use buffetfs::codec::Wire;
    use buffetfs::wire::WalkedDir;
    let attr = buffetfs::types::Attr {
        ino: Ino::new(0, 0, 1),
        kind: FileKind::Directory,
        perm: PermBlob::new(0o755, 0, 0),
        size: 0,
        nlink: 2,
        atime: 1,
        mtime: 2,
        ctime: 3,
    };
    let resp = Response::Walked {
        dirs: vec![WalkedDir {
            attr,
            entries: vec![DirEntry {
                name: "child".into(),
                ino: Ino::new(1, 0, 9),
                kind: FileKind::Directory,
                perm: PermBlob::new(0o700, 5, 5),
            }],
        }],
        walked: 1,
        next: Some(Ino::new(1, 0, 9)),
    };
    let back = Response::from_bytes(&resp.to_bytes()).unwrap();
    assert_eq!(back, resp);
}
