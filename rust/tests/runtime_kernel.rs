//! Cross-language correctness: the AOT-compiled Pallas kernel (loaded
//! via PJRT) must agree bit-for-bit with the native Rust oracle on
//! randomized inputs — the rust-side half of the L1 correctness story
//! (the python side is pytest vs ref.py).
//!
//! Gated on the `pjrt` feature: without the vendored `xla` crate there
//! is no backend to load, and tier-1 must stay green.
#![cfg(feature = "pjrt")]

use std::sync::Arc;

use buffetfs::perm::{self, BatchPathChecker, NativeBatchChecker};
use buffetfs::runtime::{shapes, KernelRuntime};
use buffetfs::types::{AccessMask, Credentials, PermBlob};
use buffetfs::util::rng::XorShift;

fn runtime() -> Arc<KernelRuntime> {
    KernelRuntime::load(KernelRuntime::default_dir()).expect("artifacts built? run `make artifacts`")
}

fn random_chain(r: &mut XorShift, max_depth: usize) -> Vec<PermBlob> {
    let depth = 1 + r.below(max_depth as u64) as usize;
    (0..depth)
        .map(|_| {
            PermBlob::new((r.below(0o1000)) as u16, r.below(8) as u32, r.below(8) as u32)
        })
        .collect()
}

fn random_cred(r: &mut XorShift) -> Credentials {
    let uid = r.below(8) as u32;
    let gid = r.below(8) as u32;
    let extra: Vec<u32> = (0..r.below(4)).map(|_| r.below(8) as u32).collect();
    Credentials::with_groups(uid, gid, extra)
}

#[test]
fn pjrt_kernel_matches_native_oracle() {
    let rt = runtime();
    let mut r = XorShift::new(0x5eed);
    for round in 0..20 {
        let cred = random_cred(&mut r);
        let want = AccessMask((r.below(8)) as u8);
        let chains: Vec<Vec<PermBlob>> =
            (0..r.range(1, 300)).map(|_| random_chain(&mut r, shapes::DEPTH_D)).collect();

        let native = NativeBatchChecker.check_paths(&chains, &cred, want).unwrap();
        let kernel = rt.check_paths(&chains, &cred, want).unwrap();
        assert_eq!(native.len(), kernel.len());
        for (i, (n, k)) in native.iter().zip(kernel.iter()).enumerate() {
            assert_eq!(
                n, k,
                "round {round} chain {i}: native={n:?} kernel={k:?} \
                 chain={:?} cred={cred:?} want={want:?}",
                chains[i]
            );
        }
    }
}

#[test]
fn pjrt_ref_artifact_matches_kernel_artifact() {
    let rt = runtime();
    let mut r = XorShift::new(0xabcd);
    let cred = random_cred(&mut r);
    let want = AccessMask::READ;
    let chains: Vec<Vec<PermBlob>> =
        (0..500).map(|_| random_chain(&mut r, shapes::DEPTH_D)).collect();
    let pallas = rt.check_paths_via(&chains, &cred, want, false).unwrap();
    let jnp_ref = rt.check_paths_via(&chains, &cred, want, true).unwrap();
    assert_eq!(pallas, jnp_ref);
}

#[test]
fn dirscan_matches_scalar_check() {
    let rt = runtime();
    let mut r = XorShift::new(0x77);
    for _ in 0..10 {
        let cred = random_cred(&mut r);
        let want = AccessMask((r.below(8)) as u8);
        let entries: Vec<PermBlob> = (0..r.range(1, 2500))
            .map(|_| PermBlob::new((r.below(0o1000)) as u16, r.below(8) as u32, r.below(8) as u32))
            .collect();
        let got = rt.dirscan(&entries, &cred, want).unwrap();
        assert_eq!(got.len(), entries.len());
        for (i, p) in entries.iter().enumerate() {
            assert_eq!(
                got[i],
                perm::check_access(p, &cred, want),
                "entry {i}: {p:?} cred={cred:?} want={want:?}"
            );
        }
    }
}

#[test]
fn deep_chains_fall_back_to_native() {
    let rt = runtime();
    let mut r = XorShift::new(0x99);
    // chains deeper than DEPTH_D can't ride the kernel; the runtime must
    // still answer correctly via the native fallback
    let chains: Vec<Vec<PermBlob>> =
        (0..40).map(|_| random_chain(&mut r, shapes::DEPTH_D * 2)).collect();
    let cred = random_cred(&mut r);
    let native = NativeBatchChecker.check_paths(&chains, &cred, AccessMask::RW).unwrap();
    let kernel = rt.check_paths(&chains, &cred, AccessMask::RW).unwrap();
    assert_eq!(native, kernel);
}

#[test]
fn root_credential_and_empty_want_edge_cases() {
    let rt = runtime();
    let chains = vec![
        vec![PermBlob::new(0o000, 5, 5)],
        vec![PermBlob::new(0o100, 5, 5), PermBlob::new(0o000, 5, 5)],
    ];
    // root: rw on anything, x only when some x bit set
    let root = Credentials::root();
    let v = rt.check_paths(&chains, &root, AccessMask::RW).unwrap();
    assert_eq!(v, vec![Ok(()), Ok(())]);
    let v = rt.check_paths(&chains, &root, AccessMask::EXEC).unwrap();
    assert_eq!(v[0], Err(0));
    // want=0 always allowed for anyone with X on ancestors
    let user = Credentials::new(5, 5);
    let v = rt.check_paths(&chains, &user, AccessMask::NONE).unwrap();
    assert_eq!(v, vec![Ok(()), Ok(())]);
}
