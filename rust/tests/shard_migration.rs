//! Elastic namespace acceptance: live directory migration, versioned
//! placement redirects, grace-window forwarding, load-driven
//! rebalancing and pool grow/shrink (DESIGN.md §12).
//!
//! The invariants under test:
//! * an acked op is never lost and never double-applied across a live
//!   migration — even with 8 mutator threads racing the handoff;
//! * a stale client pays at most ONE `WrongServer` redirect per op,
//!   then routes directly via its placement cache;
//! * open `Dir`/`File` handles survive migration — dirfd ops re-resolve
//!   their lease exactly once at the new owner, reads need no
//!   server-side open record at all;
//! * a source that crashes after the `MovedOut` commit fence recovers
//!   redirecting; a failed import rolls back with nothing leaked.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use buffetfs::agent::BAgent;
use buffetfs::api::Client;
use buffetfs::blib::Buffet;
use buffetfs::cluster::placement::{Balancer, BalancerConfig};
use buffetfs::cluster::{Backing, BuffetCluster, ClusterView};
use buffetfs::error::FsError;
use buffetfs::metrics::RpcMetrics;
use buffetfs::server::journal::JournalConfig;
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::ChanTransport;
use buffetfs::transport::Service;
use buffetfs::types::{Credentials, Ino, OpenFlags};
use buffetfs::wire::{Request, Response};

fn two_hosts() -> BuffetCluster {
    BuffetCluster::spawn_with(
        2,
        NetConfig::zero(),
        Backing::Mem,
        false, // co-located placement: /hot is born whole on host 0
        ServiceConfig::unbounded(),
    )
}

/// Drive one migration straight on the source server (what the
/// balancer's `rebalance_step` does), returning `(files, map_version)`.
fn migrate(cluster: &BuffetCluster, src: u16, dir: Ino, target: u16, grace: u32) -> (u64, u64) {
    let src = cluster.server(src).expect("source server");
    match src.handle(Request::MigrateSubtree { dir, target, grace }) {
        Response::Migrated { files, map_version } => (files, map_version),
        other => panic!("migration failed: {other:?}"),
    }
}

fn tdir(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "buffetfs-shard-{tag}-{}-{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn journal_cfg() -> JournalConfig {
    JournalConfig { sync_data: false, ..JournalConfig::default() }
}

fn quiesce(metrics: &RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

// ---------------------------------------------------------------------------
// Protocol validations
// ---------------------------------------------------------------------------

#[test]
fn migration_rejects_root_self_and_non_directories() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/d", 0o755).unwrap();
    p.put("/f", b"x").unwrap();
    let d = p.stat("/d").unwrap().ino;
    let f = p.stat("/f").unwrap().ino;
    let s = &cluster.servers[0];

    match s.handle(Request::MigrateSubtree { dir: cluster.root(), target: 1, grace: 0 }) {
        Response::Err(FsError::Invalid(_)) => {}
        other => panic!("migrating the root must be refused: {other:?}"),
    }
    match s.handle(Request::MigrateSubtree { dir: d, target: 0, grace: 0 }) {
        Response::Err(FsError::Invalid(_)) => {}
        other => panic!("self-target must be refused: {other:?}"),
    }
    match s.handle(Request::MigrateSubtree { dir: f, target: 1, grace: 0 }) {
        Response::Err(FsError::NotADirectory) => {}
        other => panic!("migrating a file must be refused: {other:?}"),
    }
    match s.handle(Request::MigrateSubtree { dir: d, target: 9, grace: 0 }) {
        Response::Err(_) => {}
        other => panic!("unknown peer must be refused: {other:?}"),
    }
    // nothing of the above left gate entries behind
    p.put("/d/ok", b"still writable").unwrap();
}

// ---------------------------------------------------------------------------
// Redirects and the placement cache
// ---------------------------------------------------------------------------

#[test]
fn migrated_subtree_serves_at_target_with_one_redirect_per_op() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    for i in 0..4 {
        p.put(&format!("/hot/f{i}"), format!("body {i}").as_bytes()).unwrap();
    }
    let hot = p.stat("/hot").unwrap().ino;

    let (files, map_version) = migrate(&cluster, 0, hot, 1, 0);
    assert_eq!(files, 5, "dir + 4 files must move");
    assert_eq!(map_version, 1);
    assert_eq!(cluster.shard_map.owner(hot), Some(1));

    // the stale client transparently follows the redirect…
    let before = agent.stats.redirects.load(Ordering::Relaxed);
    assert_eq!(p.get("/hot/f0", 64).unwrap(), b"body 0");
    let after_first = agent.stats.redirects.load(Ordering::Relaxed);
    assert!(after_first > before, "the first post-migration op must be redirected");
    assert!(after_first - before <= 2, "redirect per op is bounded (open + read)");

    // …learning each ino it touches: a full pass costs at most one
    // redirect per newly-touched ino, and a second pass costs none
    for i in 0..4 {
        assert_eq!(p.get(&format!("/hot/f{i}"), 64).unwrap(), format!("body {i}").as_bytes());
    }
    let learned = agent.stats.redirects.load(Ordering::Relaxed);
    assert!(learned - before <= 5, "at most one redirect per touched ino (dir + 4 files)");
    for i in 0..4 {
        assert_eq!(p.get(&format!("/hot/f{i}"), 64).unwrap(), format!("body {i}").as_bytes());
    }
    assert_eq!(
        agent.stats.redirects.load(Ordering::Relaxed),
        learned,
        "a primed placement cache must not be redirected again"
    );
    assert!(cluster.servers[0].stats.redirects_served.load(Ordering::Relaxed) >= 1);

    // new files under the migrated directory are minted by the new owner
    p.put("/hot/new", b"made at the target").unwrap();
    assert_eq!(p.stat("/hot/new").unwrap().ino.host, 1);
    assert_eq!(p.get("/hot/new", 64).unwrap(), b"made at the target");
}

#[test]
fn grace_budget_forwards_stragglers_then_redirects() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.put("/hot/f", b"x").unwrap();
    let hot = p.stat("/hot").unwrap().ino;
    let f = p.stat("/hot/f").unwrap().ino;

    migrate(&cluster, 0, hot, 1, 2);
    let src = &cluster.servers[0];

    // the first `grace` stragglers are forwarded whole to the new owner
    for _ in 0..2 {
        match src.handle(Request::GetAttr { ino: f }) {
            Response::AttrR(a) => assert_eq!(a.ino, f),
            other => panic!("straggler inside the grace window must be forwarded: {other:?}"),
        }
    }
    assert_eq!(src.stats.forwards.load(Ordering::Relaxed), 2);

    // the budget is spent: from now on the client is told to re-route
    match src.handle(Request::GetAttr { ino: f }) {
        Response::Err(FsError::WrongServer { owner: 1, map_version }) => {
            assert_eq!(map_version, 1);
        }
        other => panic!("expected WrongServer after the grace budget: {other:?}"),
    }
    assert!(src.stats.redirects_served.load(Ordering::Relaxed) >= 1);
}

#[test]
fn placement_fetch_primes_the_cache_and_confirms_when_current() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.put("/hot/f", b"x").unwrap();
    let hot = p.stat("/hot").unwrap().ino;
    migrate(&cluster, 0, hot, 1, 0);

    // a fresh client pre-fetches the map and is never redirected at all
    let (agent2, metrics2) = cluster.make_agent();
    assert_eq!(agent2.fetch_placement().unwrap(), 1);
    assert_eq!(agent2.placement().version(), 1);
    assert_eq!(agent2.placement().route(hot), Some(1));

    // a second fetch at the same version is an empty-delta confirmation:
    // the cached table must survive it
    assert_eq!(agent2.fetch_placement().unwrap(), 1);
    assert_eq!(agent2.placement().route(hot), Some(1));
    assert_eq!(metrics2.count("placement"), 2);

    // directory-targeted ops route straight to the new owner: no
    // WrongServer bounce at all with a pre-fetched map
    let p2 = Buffet::process(agent2.clone(), Credentials::root());
    assert!(p2.stat("/hot/f").is_ok());
    assert_eq!(
        agent2.stats.redirects.load(Ordering::Relaxed),
        0,
        "a pre-fetched placement map means zero redirects for dir-targeted ops"
    );
    // a file-ino op may pay one first-touch redirect (the map only
    // carries subtree roots), never more
    assert_eq!(p2.get("/hot/f", 64).unwrap(), b"x");
    assert!(agent2.stats.redirects.load(Ordering::Relaxed) <= 1);
}

// ---------------------------------------------------------------------------
// Open handles across a migration
// ---------------------------------------------------------------------------

#[test]
fn open_handles_survive_migration_with_exactly_one_lease_reresolve() {
    let cluster = two_hosts();
    let (agent, metrics) = cluster.make_agent();
    let admin = Client::new(agent.clone(), Credentials::root());
    let root = admin.root().unwrap();
    let hot = root.mkdir("hot", 0o777).unwrap();
    let f = hot.create("f0", 0o644).unwrap();
    f.write(b"before the move").unwrap();
    f.fsync().unwrap();
    // keep `hot` (a leased dirfd) and a read handle open across the move
    let g = hot.open_file("f0", OpenFlags::RDONLY).unwrap();
    quiesce(&metrics);

    migrate(&cluster, 0, hot.node(), 1, 0);

    // the dirfd op: one WrongServer redirect, one StaleLease re-resolve
    let stale_before = metrics.stale_retries("getattr");
    let redirects_before = agent.stats.redirects.load(Ordering::Relaxed);
    let attr = hot.stat("f0").unwrap();
    assert_eq!(attr.size, 15);
    assert_eq!(
        metrics.stale_retries("getattr"),
        stale_before + 1,
        "the revoked lease must re-resolve exactly once"
    );
    assert!(agent.stats.redirects.load(Ordering::Relaxed) > redirects_before);

    // …and only once: the same handle is now warm at the new owner
    let settled = (metrics.stale_retries("getattr"), agent.stats.redirects.load(Ordering::Relaxed));
    hot.stat("f0").unwrap();
    assert_eq!(
        (metrics.stale_retries("getattr"), agent.stats.redirects.load(Ordering::Relaxed)),
        settled,
        "later dirfd ops must be free of both redirects and stale retries"
    );

    // the open file handle keeps reading — no server-side open record
    // needed, the data migrated with the subtree
    assert_eq!(g.read_at(0, 64).unwrap(), b"before the move");
    g.close().unwrap();

    // creation through the surviving dirfd is minted by the new owner
    let h = hot.create("after", 0o644).unwrap();
    assert_eq!(h.ino().host, 1);
    h.close().unwrap();
    let _ = f.close();
}

// ---------------------------------------------------------------------------
// Rename racing a migration
// ---------------------------------------------------------------------------

#[test]
fn rename_within_a_migrated_directory_applies_exactly_once() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.put("/hot/a", b"payload").unwrap();
    let hot = p.stat("/hot").unwrap().ino;
    migrate(&cluster, 0, hot, 1, 0);

    // the stale client's rename redirects, then applies exactly once
    p.rename("/hot/a", "/hot/b").unwrap();
    assert_eq!(p.stat("/hot/a").unwrap_err(), FsError::NotFound);
    assert_eq!(p.get("/hot/b", 64).unwrap(), b"payload");
    // a literal retry is AlreadyApplied territory: the source is gone
    assert_eq!(p.rename("/hot/a", "/hot/b").unwrap_err(), FsError::NotFound);
}

#[test]
fn rename_into_a_migrated_directory_lands_at_exactly_one_name() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.mkdir("/cold", 0o755).unwrap();
    p.put("/cold/x", b"crossing").unwrap();
    let hot = p.stat("/hot").unwrap().ino;
    migrate(&cluster, 0, hot, 1, 0);

    // source dir still lives on host 0, destination now on host 1: the
    // rename either completes (redirect followed) or fails cleanly —
    // but the file is at exactly one of the two names, with its bytes
    let res = p.rename("/cold/x", "/hot/y");
    let at_src = p.stat("/cold/x").is_ok();
    let at_dst = p.stat("/hot/y").is_ok();
    assert!(
        at_src != at_dst,
        "rename racing migration must land at exactly one name (res={res:?} src={at_src} dst={at_dst})"
    );
    if res.is_ok() {
        assert!(at_dst, "an acked rename must be visible at the destination");
    }
    let kept = if at_dst { "/hot/y" } else { "/cold/x" };
    assert_eq!(p.get(kept, 64).unwrap(), b"crossing");
}

// ---------------------------------------------------------------------------
// Ops on a migrated subtree ROOT through its still-local parent dirent
// ---------------------------------------------------------------------------
// The subtree root is the one migrated object whose dirent stays behind
// on the source: its parent directory never moved. Rmdir/rename arrive
// at the source via that dirent, so the source must treat the evicted
// body as remote and route to the placement owner — not take the
// owns-it-locally branch against its own tombstone.

#[test]
fn rmdir_of_a_migrated_subtree_root_routes_to_the_new_owner() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/parent", 0o755).unwrap();
    p.mkdir("/parent/sub", 0o755).unwrap();
    p.put("/parent/sub/f", b"x").unwrap();
    let sub = p.stat("/parent/sub").unwrap().ino;
    migrate(&cluster, 0, sub, 1, 0);

    // non-empty: emptiness is decided by the CURRENT owner's copy, and
    // the refusal leaves both the dirent and the body fully intact
    assert_eq!(p.rmdir("/parent/sub").unwrap_err(), FsError::NotEmpty);
    assert!(p.stat("/parent/sub").is_ok());
    assert_eq!(p.get("/parent/sub/f", 64).unwrap(), b"x");

    // emptied, the rmdir succeeds: the source drops its dirent and the
    // new owner drops the directory body — nothing orphaned either side
    p.unlink("/parent/sub/f").unwrap();
    p.rmdir("/parent/sub").unwrap();
    assert_eq!(p.stat("/parent/sub").unwrap_err(), FsError::NotFound);
    assert!(
        cluster.servers[1].fs.getattr(sub.file).is_err(),
        "the migrated body must be dropped at the owner"
    );
    // the parent keeps working on the source afterwards
    p.put("/parent/again", b"still writable").unwrap();
}

#[test]
fn rename_of_a_migrated_subtree_root_updates_the_owners_parent_meta() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let p = Buffet::process(agent, Credentials::root());
    p.mkdir("/a", 0o755).unwrap();
    p.mkdir("/b", 0o755).unwrap();
    p.mkdir("/a/sub", 0o755).unwrap();
    p.put("/a/sub/f", b"payload").unwrap();
    let sub = p.stat("/a/sub").unwrap().ino;
    let b = p.stat("/b").unwrap().ino;
    migrate(&cluster, 0, sub, 1, 0);

    // the dirent moves on the source; the body stays with the new owner
    p.rename("/a/sub", "/b/sub2").unwrap();
    assert_eq!(p.stat("/a/sub").unwrap_err(), FsError::NotFound);
    let moved = p.stat("/b/sub2").unwrap();
    assert_eq!(moved.ino, sub, "rename moves the dirent, not the object");
    assert_eq!(p.get("/b/sub2/f", 64).unwrap(), b"payload");

    // and the owner's inode bookkeeping followed the dirent, so later
    // chmod/chown dirent-syncs chase the entry to its new directory
    let (parent, name) = cluster.servers[1]
        .fs
        .parent_of(sub.file)
        .unwrap()
        .expect("a migrated subtree root keeps its parent pointer");
    assert_eq!(parent, b, "owner's parent pointer must follow the rename");
    assert_eq!(name, "sub2", "owner's name bookkeeping must follow the rename");
}

// ---------------------------------------------------------------------------
// The storm: 8 mutator threads racing a live migration
// ---------------------------------------------------------------------------

enum Fate {
    At(String),
    Gone(String),
    AtOneOf(String, String),
    Bytes(String, Vec<u8>),
}

/// One storm worker on paths unique to `w`, all under `dir`. Ops whose
/// final RPC errored (e.g. the freeze-window `Busy` budget ran out) are
/// indeterminate and recorded only as loosely as the truth allows;
/// double-applies panic on the spot.
fn storm_worker(p: &Buffet, dir: &str, w: u32, ops: u32, fates: &Mutex<Vec<Fate>>, errors: &AtomicU64) {
    let mut mine = Vec::new();
    for i in 0..ops {
        if i % 4 == 3 {
            let path = format!("{dir}/p{w}x{i}");
            let body = format!("storm body {w}/{i}").into_bytes();
            match p.put(&path, &body) {
                Ok(()) => mine.push(Fate::Bytes(path, body)),
                Err(_) => {
                    errors.fetch_add(1, Ordering::Relaxed);
                }
            }
            continue;
        }
        let a = format!("{dir}/c{w}x{i}");
        let b = format!("{dir}/c{w}x{i}r");
        match p.create(&a, 0o644) {
            Ok(_) => {}
            Err(FsError::AlreadyExists) => {
                panic!("exactly-once violated: create {a} applied twice")
            }
            Err(_) => {
                errors.fetch_add(1, Ordering::Relaxed);
                continue;
            }
        }
        match p.rename(&a, &b) {
            Ok(()) => mine.push(Fate::Gone(a)),
            Err(FsError::NotFound) => {
                panic!("exactly-once violated: rename {a} applied twice")
            }
            Err(_) => {
                errors.fetch_add(1, Ordering::Relaxed);
                mine.push(Fate::AtOneOf(a, b));
                continue;
            }
        }
        match p.unlink(&b) {
            Ok(()) if i % 3 == 0 => {
                mine.push(Fate::Gone(b));
                continue;
            }
            Ok(()) => {
                // re-create so At(b) still holds below
                match p.put(&b, b"recreated") {
                    Ok(()) => mine.push(Fate::At(b)),
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
                continue;
            }
            Err(FsError::NotFound) => panic!("exactly-once violated: unlink {b} applied twice"),
            Err(_) => {
                errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    fates.lock().unwrap().extend(mine);
}

fn sweep(p: &Buffet, fates: &[Fate]) {
    for f in fates {
        match f {
            Fate::At(path) => {
                p.stat(path).unwrap_or_else(|e| panic!("acked {path} lost: {e:?}"));
            }
            Fate::Gone(path) => match p.stat(path) {
                Err(FsError::NotFound) => {}
                other => panic!("acked removal of {path} undone: {other:?}"),
            },
            Fate::AtOneOf(a, b) => {
                let (at_a, at_b) = (p.stat(a).is_ok(), p.stat(b).is_ok());
                assert!(
                    at_a != at_b,
                    "exactly-once violated: {a}={at_a} {b}={at_b} (must be at exactly one)"
                );
            }
            Fate::Bytes(path, body) => {
                let got =
                    p.get(path, 1 << 16).unwrap_or_else(|e| panic!("acked {path} lost: {e:?}"));
                assert_eq!(&got, body, "{path} bytes diverged");
            }
        }
    }
}

#[test]
fn live_migration_under_mutation_storm_loses_no_acked_op() {
    let cluster = two_hosts();
    let (agent, _) = cluster.make_agent();
    let admin = Buffet::process(agent.clone(), Credentials::root());
    admin.mkdir("/hot", 0o777).unwrap();
    let hot = admin.stat("/hot").unwrap().ino;

    let fates = Mutex::new(Vec::new());
    let errors = AtomicU64::new(0);
    let migrated = std::thread::scope(|scope| {
        for w in 0..8u32 {
            let agent = agent.clone();
            let (fates, errors) = (&fates, &errors);
            scope.spawn(move || {
                let p = Buffet::with_pid(agent, 100 + w, Credentials::root());
                storm_worker(&p, "/hot", w, 40, fates, errors);
            });
        }
        // mid-storm, the balancer decides /hot belongs on host 1: the
        // freeze gate bounces racing mutators into their bounded
        // busy-retry loop, the drain barriers behind in-flight ops
        std::thread::sleep(Duration::from_millis(3));
        migrate(&cluster, 0, hot, 1, 64)
    });
    assert!(migrated.0 >= 1, "the storm directory must have moved");
    assert_eq!(cluster.shard_map.owner(hot), Some(1));

    // verify from a FRESH client (cold placement cache): every acked op
    // is present exactly once at the new owner, each sweep op needing
    // at most one redirect before the cache is primed
    let (agent2, _) = cluster.make_agent();
    let p2 = Buffet::with_pid(agent2.clone(), 999, Credentials::root());
    let fates = fates.into_inner().unwrap();
    assert!(!fates.is_empty(), "the storm must ack some ops");
    sweep(&p2, &fates);
    let sweep_ops = fates.len() as u64 * 2;
    assert!(
        agent2.stats.redirects.load(Ordering::Relaxed) <= sweep_ops,
        "client blip is bounded: at most one redirect retry per op"
    );

    // and the storm's directory keeps taking new work at the target
    p2.put("/hot/coda", b"after the storm").unwrap();
    assert_eq!(p2.stat("/hot/coda").unwrap().ino.host, 1);
}

// ---------------------------------------------------------------------------
// Crash safety: the MovedOut commit fence
// ---------------------------------------------------------------------------

#[test]
fn source_crash_after_handoff_recovers_redirecting_with_no_acked_op_lost() {
    let sdir = tdir("src");
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let tgt = BServer::new(LocalFs::new(1, 0, Box::new(MemData::new())));
    tgt.enable_elastic();

    let mut acked: Vec<(String, Vec<u8>)> = Vec::new();
    let hot;
    {
        let src = BServer::recover(0, 0, Box::new(MemData::new()), &sdir, journal_cfg()).unwrap();
        src.enable_elastic();
        src.add_peer(1, ChanTransport::new(tgt.clone(), net.clone(), Arc::new(RpcMetrics::new())));
        tgt.add_peer(0, ChanTransport::new(src.clone(), net.clone(), Arc::new(RpcMetrics::new())));

        let metrics = Arc::new(RpcMetrics::new());
        let view = ClusterView::new(src.fs.root_ino());
        view.add(0, 0, ChanTransport::new(src.clone(), net.clone(), metrics.clone()));
        view.add(1, 0, ChanTransport::new(tgt.clone(), net.clone(), metrics.clone()));
        let p = Buffet::process(BAgent::new(1, view, metrics), Credentials::root());

        p.mkdir("/hot", 0o755).unwrap();
        for i in 0..20 {
            let path = format!("/hot/f{i}");
            let body = format!("durable {i}").into_bytes();
            p.put(&path, &body).unwrap();
            acked.push((path, body));
        }
        hot = p.stat("/hot").unwrap().ino;
        match src.handle(Request::MigrateSubtree { dir: hot, target: 1, grace: 0 }) {
            Response::Migrated { files, .. } => assert_eq!(files, 21),
            other => panic!("migration failed: {other:?}"),
        }
        // the source machine dies here: all in-memory state is gone,
        // only its journal directory (with the MovedOut fence) survives
    }

    let src2 = BServer::recover(0, 0, Box::new(MemData::new()), &sdir, journal_cfg()).unwrap();
    src2.enable_elastic();
    let metrics = Arc::new(RpcMetrics::new());
    let view = ClusterView::new(src2.fs.root_ino());
    view.add(0, 0, ChanTransport::new(src2.clone(), net.clone(), metrics.clone()));
    view.add(1, 0, ChanTransport::new(tgt.clone(), net, metrics.clone()));
    let agent = BAgent::new(2, view, metrics);
    let p = Buffet::process(agent.clone(), Credentials::root());

    // replayed MovedOut records make the reborn source redirect — every
    // acked byte is served by the target, nothing lost, nothing doubled
    for (path, body) in &acked {
        let got = p
            .get(path, 1 << 16)
            .unwrap_or_else(|e| panic!("acked {path} lost across the source crash: {e:?}"));
        assert_eq!(&got, body, "{path} bytes diverged across the source crash");
    }
    assert!(agent.stats.redirects.load(Ordering::Relaxed) >= 1);
    assert!(src2.stats.redirects_served.load(Ordering::Relaxed) >= 1);
    // and the reborn source did not resurrect the migrated subtree
    assert!(!src2.fs.owns(hot) || src2.fs.getattr(hot.file).is_err());
    let _ = std::fs::remove_dir_all(&sdir);
}

#[test]
fn failed_import_rolls_back_and_the_source_keeps_serving() {
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let src = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    src.enable_elastic();
    // the target never opted into elastic mode: it refuses the import
    let tgt = BServer::new(LocalFs::new(1, 0, Box::new(MemData::new())));
    src.add_peer(1, ChanTransport::new(tgt.clone(), net.clone(), Arc::new(RpcMetrics::new())));

    let metrics = Arc::new(RpcMetrics::new());
    let view = ClusterView::new(src.fs.root_ino());
    view.add(0, 0, ChanTransport::new(src.clone(), net, metrics.clone()));
    let p = Buffet::process(BAgent::new(1, view, metrics), Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    p.put("/hot/a", b"stays home").unwrap();
    let hot = p.stat("/hot").unwrap().ino;

    match src.handle(Request::MigrateSubtree { dir: hot, target: 1, grace: 4 }) {
        Response::Err(FsError::PermissionDenied) => {}
        other => panic!("a non-elastic target must refuse the import: {other:?}"),
    }

    // full rollback: the map never flipped, no gate entries linger, the
    // subtree serves locally with zero redirects
    assert_eq!(src.shard_map.version(), 0);
    assert_eq!(src.shard_map.owner(hot), None);
    assert_eq!(p.get("/hot/a", 64).unwrap(), b"stays home");
    p.put("/hot/b", b"still writable").unwrap();
    assert_eq!(src.stats.redirects_served.load(Ordering::Relaxed), 0);
    assert_eq!(src.stats.forwards.load(Ordering::Relaxed), 0);
    assert_eq!(src.stats.migrated_dirs.load(Ordering::Relaxed), 0);
}

// ---------------------------------------------------------------------------
// Elastic pool: grow, load-driven rebalance, shrink
// ---------------------------------------------------------------------------

#[test]
fn grow_rebalance_and_shrink_roundtrip() {
    let cluster = BuffetCluster::spawn_with(
        1,
        NetConfig::zero(),
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    );
    let (agent, metrics) = cluster.make_agent();
    let p = Buffet::process(agent.clone(), Credentials::root());
    p.mkdir("/hot", 0o755).unwrap();
    for i in 0..8 {
        p.put(&format!("/hot/f{i}"), format!("hot {i}").as_bytes()).unwrap();
    }
    p.put("/background", b"root traffic").unwrap();
    let hot = p.stat("/hot").unwrap().ino;

    // an empty newcomer joins the pool and is wired into the live client
    let newcomer = cluster.grow();
    assert_eq!(newcomer, 1);
    assert!(cluster.server(1).is_some());

    // drive a hot spot: mutations under /hot dominate the op-rate
    // accounting (writes always reach the server; reads may be served
    // out of client caches and would count nothing)
    for round in 0..25 {
        for i in 0..8 {
            p.put(&format!("/hot/f{i}"), format!("hot {i}").as_bytes())
                .unwrap_or_else(|e| panic!("round {round}: {e:?}"));
        }
    }
    p.stat("/background").unwrap();

    // grace 0 keeps the client-visible effect deterministic below: the
    // first straggler op is redirected, not silently forwarded
    let balancer = Balancer::new(BalancerConfig { imbalance: 1.2, min_total_ops: 16, grace: 0 });
    let plan = cluster
        .rebalance_step(&balancer)
        .unwrap()
        .expect("a lopsided load must produce a plan");
    assert_eq!(plan.dir, hot, "the hottest directory moves");
    assert_eq!(plan.from, 0);
    assert_eq!(plan.to, 1);
    assert_eq!(cluster.shard_map.owner(hot), Some(1));

    // the pool cannot shrink while the newcomer owns a subtree
    assert_eq!(cluster.shrink(1).unwrap_err(), FsError::Busy);

    // the live client keeps reading through the move (≤1 redirect each)
    assert_eq!(p.get("/hot/f0", 64).unwrap(), b"hot 0");
    assert!(agent.stats.redirects.load(Ordering::Relaxed) >= 1);

    // drain the newcomer: migrate the subtree back home…
    quiesce(&metrics); // let the async close tail drain first
    migrate(&cluster, 1, hot, 0, 0);
    assert_eq!(
        cluster.shard_map.owner(hot),
        None,
        "returning home erases the override instead of stacking one"
    );
    // …and now the pool contracts
    cluster.shrink(1).unwrap();
    assert!(cluster.server(1).is_none());

    // the client's placement cache may still say host 1; the route
    // falls back to the birth server, which owns the subtree again
    assert_eq!(p.get("/hot/f3", 64).unwrap(), b"hot 3");
    p.put("/hot/back-home", b"written after shrink").unwrap();
    assert_eq!(p.stat("/hot/back-home").unwrap().ino.host, 0);
}
