//! Speculative metadata write-behind acceptance (DESIGN.md §14):
//!
//! * an untar-shaped create burst acknowledges locally and drains as
//!   ONE `MetaBatch` RPC (`specflush`), never-registered opens elided;
//! * speculated state is self-consistent before the server hears of it
//!   (the file is openable and writable at zero RPCs);
//! * a server-side EEXIST surfaces exactly ONCE, at the next barrier;
//! * a failed speculative mkdir rolls back its dependent children;
//! * `unlink` of an unflushed speculative create elides both ops;
//! * a pre-§14 server downgrades stickily to sequential replay;
//! * kill-the-primary mid-storm: zero acked-at-barrier ops lost, none
//!   double-applied (the per-item dedup ledger survives promotion).

use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use buffetfs::agent::spec::{is_provisional, SpecConfig};
use buffetfs::agent::BAgent;
use buffetfs::api::Client;
use buffetfs::blib::Buffet;
use buffetfs::cluster::{Backing, BuffetCluster, ClusterView};
use buffetfs::datapath::DatapathConfig;
use buffetfs::error::FsError;
use buffetfs::metrics::RpcMetrics;
use buffetfs::server::journal::JournalConfig;
use buffetfs::server::BServer;
use buffetfs::simnet::{LatencyModel, NetConfig};
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::capacity::ServiceConfig;
use buffetfs::transport::chan::ChanTransport;
use buffetfs::transport::Service;
use buffetfs::types::{Credentials, FileKind, OpenFlags};
use buffetfs::util::rng::XorShift;
use buffetfs::wire::{Request, Response};

fn fast_cluster() -> BuffetCluster {
    BuffetCluster::spawn_with(
        1,
        NetConfig { one_way_us: 0, per_kb_us: 0, jitter_us: 0, seed: 14 },
        Backing::Mem,
        false,
        ServiceConfig::unbounded(),
    )
}

fn quiesce(metrics: &RpcMetrics) {
    let mut last = metrics.total_rpcs();
    for _ in 0..100 {
        std::thread::sleep(Duration::from_millis(5));
        let now = metrics.total_rpcs();
        if now == last {
            return;
        }
        last = now;
    }
}

// ---------------------------------------------------------------------------
// The tentpole effect: N metadata mutations, ~1 critical-path RPC.
// ---------------------------------------------------------------------------

#[test]
fn untar_burst_coalesces_into_one_batch_rpc() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let client = Client::new(agent.clone(), Credentials::root());
    let pool = client.root().unwrap().mkdir("pool", 0o755).unwrap();
    agent.enable_speculation(SpecConfig::default());
    pool.readdir().unwrap(); // warm the listing: speculation needs a decided cache
    quiesce(&metrics);
    let meta0 = metrics.metadata_rpcs();

    for i in 0..32 {
        let f = pool.create(&format!("f{i}"), 0o644).unwrap();
        assert!(is_provisional(f.ino()), "speculated create must carry a provisional ino");
        f.close().unwrap();
    }
    assert_eq!(metrics.metadata_rpcs(), meta0, "the burst must be acknowledged locally");
    assert_eq!(agent.spec_pending_ops(), 64, "32 creates + 32 deferred closes queued");
    assert_eq!(metrics.spec_queued(), 32);

    agent.spec_drain().unwrap();
    assert_eq!(metrics.count("specflush"), 1, "one MetaBatch drains the whole chain");
    assert!(
        metrics.metadata_rpcs() - meta0 <= 2,
        "32 creates + 32 closes must cost ~1 metadata RPC, cost {}",
        metrics.metadata_rpcs() - meta0
    );
    // the deferred opens never reached the server: their closes elide
    assert_eq!(metrics.spec_elided(), 32);
    assert_eq!(agent.spec_pending_ops(), 0);

    // a second, cache-cold agent sees all 32 files under real inos
    let (a2, _m2) = cluster.make_agent();
    let c2 = Client::new(a2, Credentials::root());
    let listing = c2.root().unwrap().open_dir("pool").unwrap().readdir().unwrap();
    assert_eq!(listing.len(), 32);
    for e in &listing {
        assert_eq!(e.kind, FileKind::Regular);
        assert!(!is_provisional(e.ino), "provisional inos must never cross the wire");
    }
}

#[test]
fn speculated_file_is_usable_locally_before_any_rpc() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    agent.enable_datapath(DatapathConfig::default());
    let client = Client::new(agent.clone(), Credentials::root());
    let d = client.root().unwrap().mkdir("d", 0o755).unwrap();
    agent.enable_speculation(SpecConfig::default());
    d.readdir().unwrap();
    quiesce(&metrics);
    let rpcs0 = metrics.total_rpcs();

    let body = b"speculation: ack first, tell the server later";
    let f = d.create("song", 0o644).unwrap();
    assert!(is_provisional(f.ino()));
    assert_eq!(f.write(body).unwrap() as usize, body.len());
    // a sibling open resolves from the speculated cache entry
    let g = d.open_file("song", OpenFlags::RDONLY).unwrap();
    assert!(is_provisional(g.ino()));
    assert_eq!(
        metrics.total_rpcs(),
        rpcs0,
        "create + write-back write + sibling open must cost ZERO RPCs"
    );

    // fsync is a barrier: materialize the create, then flush the bytes
    f.fsync().unwrap();
    let real = agent.spec_live_ino(f.ino());
    assert!(!is_provisional(real), "fsync must have materialized the ino");
    f.close().unwrap();
    g.close().unwrap();

    // a second agent observes the materialized file, bytes and all
    let (a2, _m2) = cluster.make_agent();
    let b2 = Buffet::process(a2, Credentials::root());
    assert_eq!(b2.get("/d/song", 1 << 16).unwrap(), body);
}

// ---------------------------------------------------------------------------
// Failure semantics: exactly-once error surfacing, dependent rollback.
// ---------------------------------------------------------------------------

#[test]
fn eexist_surfaces_exactly_once_at_the_next_barrier() {
    let cluster = fast_cluster();
    let (a1, m1) = cluster.make_agent();
    let (a2, _m2) = cluster.make_agent();
    let c1 = Client::new(a1.clone(), Credentials::root());
    let pool = c1.root().unwrap().mkdir("pool", 0o755).unwrap();
    a1.enable_speculation(SpecConfig::default());
    pool.readdir().unwrap(); // decisively absent, as far as a1 knows

    // another client wins the name server-side; a1's cache is now stale
    let winner = b"the server-side winner";
    let b2 = Buffet::process(a2, Credentials::root());
    b2.put("/pool/clash", winner).unwrap();

    // the speculative create still acks locally against the stale cache
    let f = pool.create("clash", 0o644).unwrap();
    assert!(is_provisional(f.ino()));
    f.close().unwrap();

    // barrier #1: the flush hits EEXIST — surfaced here, exactly once
    let err = pool.readdir().unwrap_err();
    assert_eq!(err, FsError::AlreadyExists);
    assert!(m1.spec_rollbacks() >= 1, "the failed create must roll back");

    // barrier #2: the latch was consumed; the directory reads clean
    pool.readdir().unwrap();
    assert_eq!(a1.spec_pending_ops(), 0);

    // the winner's file was never disturbed
    assert_eq!(b2.get("/pool/clash", 1 << 16).unwrap(), winner);
}

#[test]
fn failed_speculative_mkdir_rolls_back_dependent_children() {
    let cluster = fast_cluster();
    let (a1, m1) = cluster.make_agent();
    let (a2, _m2) = cluster.make_agent();
    let c1 = Client::new(a1.clone(), Credentials::root());
    let root = c1.root().unwrap();
    a1.enable_speculation(SpecConfig::default());
    root.readdir().unwrap(); // warm: "d" decisively absent

    // a FILE lands at /d behind a1's back: the speculative mkdir is doomed
    let b2 = Buffet::process(a2, Credentials::root());
    b2.put("/d", b"a file where a dir was speculated").unwrap();

    let d = root.mkdir("d", 0o755).unwrap();
    assert!(is_provisional(d.node()));
    // children speculate under the provisional directory at zero RPCs
    d.create("x", 0o644).unwrap().close().unwrap();
    d.create("y", 0o644).unwrap().close().unwrap();
    assert!(a1.spec_pending_ops() >= 3);

    // the drain is a barrier: ONE error for the whole dependent tree
    let err = a1.spec_drain().unwrap_err();
    assert_eq!(err, FsError::AlreadyExists);
    assert!(
        m1.spec_rollbacks() >= 3,
        "mkdir + both dependent creates must roll back, saw {}",
        m1.spec_rollbacks()
    );
    a1.spec_drain().unwrap(); // consumed: the second barrier is clean
    assert_eq!(a1.spec_pending_ops(), 0);

    // the rolled-back directory handle is dead — as if it never existed
    assert_eq!(d.stat_self().unwrap_err(), FsError::NotFound);
}

#[test]
fn unlink_after_speculative_create_elides_both_ops() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let b = Buffet::process(agent.clone(), Credentials::root());
    b.mkdir("/d", 0o755).unwrap();
    agent.enable_speculation(SpecConfig::default());
    b.readdir("/d").unwrap();
    quiesce(&metrics);
    let meta0 = metrics.metadata_rpcs();

    b.create("/d/tmp", 0o644).unwrap();
    b.unlink("/d/tmp").unwrap();
    assert_eq!(metrics.spec_elided(), 2, "create + unlink must cancel out");
    assert_eq!(agent.spec_pending_ops(), 0, "nothing left to flush");

    agent.spec_drain().unwrap();
    assert_eq!(metrics.count("specflush"), 0, "neither op may reach the wire");
    assert_eq!(metrics.metadata_rpcs(), meta0);
    assert!(b.readdir("/d").unwrap().is_empty());
}

#[test]
fn same_dir_rename_rides_the_chain() {
    let cluster = fast_cluster();
    let (agent, metrics) = cluster.make_agent();
    let client = Client::new(agent.clone(), Credentials::root());
    let pool = client.root().unwrap().mkdir("pool", 0o755).unwrap();
    agent.enable_speculation(SpecConfig::default());
    pool.readdir().unwrap();
    quiesce(&metrics);
    let meta0 = metrics.metadata_rpcs();

    pool.create("draft", 0o644).unwrap().close().unwrap();
    pool.rename_into("draft", &pool, "final").unwrap();
    assert_eq!(metrics.metadata_rpcs(), meta0, "create + rename both ack locally");

    agent.spec_drain().unwrap();
    let (a2, _m2) = cluster.make_agent();
    let c2 = Client::new(a2, Credentials::root());
    let names: Vec<String> = c2
        .root()
        .unwrap()
        .open_dir("pool")
        .unwrap()
        .readdir()
        .unwrap()
        .into_iter()
        .map(|e| e.name)
        .collect();
    assert_eq!(names, vec!["final".to_string()]);
}

// ---------------------------------------------------------------------------
// Protocol downgrade against a pre-§14 server.
// ---------------------------------------------------------------------------

/// A server from before wire tag 43 existed: `MetaBatch` bounces with
/// the decoder's protocol error, everything else works.
struct PreSpecServer {
    inner: Arc<BServer>,
}

impl Service for PreSpecServer {
    fn handle(&self, req: Request) -> Response {
        match req {
            Request::MetaBatch { .. } => {
                Response::Err(FsError::Protocol("bad request tag 43".into()))
            }
            other => self.inner.handle(other),
        }
    }
}

#[test]
fn pre_spec_server_downgrades_stickily_to_sequential_replay() {
    let s = BServer::new(LocalFs::new(0, 0, Box::new(MemData::new())));
    let metrics = Arc::new(RpcMetrics::new());
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let view = ClusterView::new(s.fs.root_ino());
    view.add(0, 0, ChanTransport::new(Arc::new(PreSpecServer { inner: s }), net, metrics.clone()));
    let agent = BAgent::new(1, view, metrics.clone());
    agent.enable_speculation(SpecConfig::default());
    let b = Buffet::with_pid(agent.clone(), 1, Credentials::root());
    b.readdir("/").unwrap();

    b.create("/a", 0o644).unwrap();
    b.create("/b", 0o644).unwrap();
    assert!(agent.speculation_enabled());

    // the batch bounces; the chain replays as per-op calls and succeeds
    agent.spec_drain().unwrap();
    assert!(!agent.speculation_enabled(), "the downgrade must be sticky");
    assert!(metrics.count("create") >= 2, "the chain must replay as per-op RPCs");
    assert_eq!(b.stat("/a").unwrap().kind, FileKind::Regular);
    assert_eq!(b.stat("/b").unwrap().kind, FileKind::Regular);

    // later mutations skip speculation entirely
    b.create("/c", 0o644).unwrap();
    assert_eq!(agent.spec_pending_ops(), 0);
    assert_eq!(b.stat("/c").unwrap().kind, FileKind::Regular);
}

// ---------------------------------------------------------------------------
// Crash safety: kill the primary mid-storm with speculation ON.
// ---------------------------------------------------------------------------

fn tdir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("buffetfs-spec-{tag}-{}", std::process::id()))
}

fn journal_cfg() -> JournalConfig {
    JournalConfig { sync_data: false, ..JournalConfig::default() }
}

/// Hard-drop wrapper (mirrors the crash-safety suite): after
/// `countdown` admitted requests the primary is dead — every later
/// request answers a transport error.
struct KillSwitch {
    inner: Arc<BServer>,
    countdown: AtomicU64,
    dead: AtomicBool,
}

impl Service for KillSwitch {
    fn handle(&self, req: Request) -> Response {
        if self.dead.load(Ordering::Acquire) {
            return Response::Err(FsError::Transport("primary crashed".into()));
        }
        let prev = self.countdown.fetch_sub(1, Ordering::AcqRel);
        if prev <= 1 {
            self.dead.store(true, Ordering::Release);
            return Response::Err(FsError::Transport("primary crashed".into()));
        }
        self.inner.handle(req)
    }
}

/// The invariant under test: an op is *acked* only when a later barrier
/// (`spec_drain` returning `Ok`) covered it. Zero acked ops may be lost
/// across the failover, and no create may apply twice (the blind batch
/// retry after promotion must dedup through the shipped ledger).
#[test]
fn kill_primary_mid_spec_storm_loses_no_acked_op_and_doubles_none() {
    let pdir = tdir("prim");
    let bdir = tdir("back");
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
    let net = Arc::new(LatencyModel::new(NetConfig::zero()));
    let primary = BServer::recover(0, 0, Box::new(MemData::new()), &pdir, journal_cfg()).unwrap();
    let backup = BServer::recover(0, 0, Box::new(MemData::new()), &bdir, journal_cfg()).unwrap();
    backup.enable_backup_role();
    primary.set_backup(ChanTransport::new(backup.clone(), net.clone(), Arc::new(RpcMetrics::new())));

    let mut rng = XorShift::new(0x5bec);
    let kill = Arc::new(KillSwitch {
        inner: primary.clone(),
        countdown: AtomicU64::new(80 + rng.below(80)),
        dead: AtomicBool::new(false),
    });
    let metrics = Arc::new(RpcMetrics::new());
    let view = ClusterView::new(primary.fs.root_ino());
    view.add(0, 0, ChanTransport::new(kill, net.clone(), metrics.clone()));
    view.register_standby(0, 0, ChanTransport::new(backup.clone(), net, metrics.clone()));
    let agent = BAgent::new(1, view, metrics.clone());
    agent.enable_speculation(SpecConfig::default());

    let b = Buffet::with_pid(agent.clone(), 100, Credentials::root());
    for k in 0..4 {
        b.mkdir(&format!("/d{k}"), 0o755).unwrap();
        b.readdir(&format!("/d{k}")).unwrap(); // decided cache → speculation live
    }

    // acked[path] = expected payload (empty vec for bare creates)
    let mut acked_alive: Vec<(String, Vec<u8>)> = Vec::new();
    let mut acked_removed: Vec<String> = Vec::new();
    for round in 0..120u32 {
        let dirp = format!("/d{}", round % 4);
        let mut pending_creates: Vec<(String, Vec<u8>)> = Vec::new();
        let mut pending_unlink: Option<String> = None;
        let mut poisoned = false;
        for j in 0..6u32 {
            let path = format!("{dirp}/r{round}-f{j}");
            match b.create(&path, 0o644) {
                Ok(_) => pending_creates.push((path, Vec::new())),
                Err(_) => poisoned = true,
            }
        }
        if round % 3 == 0 {
            // a put materializes its create mid-chain (write ⇒ reify)
            let path = format!("{dirp}/r{round}-data");
            let body = format!("payload {round}").into_bytes();
            match b.put(&path, &body) {
                Ok(()) => pending_creates.push((path, body)),
                Err(_) => poisoned = true,
            }
        }
        if round % 4 == 3 && acked_alive.len() > 4 {
            let (victim, _) = acked_alive.remove(0);
            match b.unlink(&victim) {
                Ok(()) => pending_unlink = Some(victim),
                Err(_) => poisoned = true,
            }
        }
        // the barrier: only a clean drain acknowledges the round
        match agent.spec_drain() {
            Ok(()) if !poisoned => {
                acked_alive.extend(pending_creates);
                acked_removed.extend(pending_unlink);
            }
            Ok(()) => {}
            Err(e) => {
                // a semantic error here would mean a double-applied
                // create (EEXIST) or a lost acked file (NOENT)
                assert!(
                    matches!(e, FsError::Transport(_) | FsError::Busy | FsError::Stale),
                    "spec storm surfaced a semantic error: {e:?}"
                );
            }
        }
    }
    assert!(metrics.failovers() >= 1, "the kill switch must have driven a promotion");
    assert!(acked_alive.len() >= 50, "too few acked ops to be meaningful");
    assert!(!acked_removed.is_empty(), "some acked unlinks must have happened");

    // drain any tail; only transport-ish errors are tolerable
    for _ in 0..16 {
        match agent.spec_drain() {
            Ok(()) => break,
            Err(e) => assert!(
                !matches!(e, FsError::AlreadyExists),
                "post-storm drain surfaced a double-apply: {e:?}"
            ),
        }
    }

    // every acked-at-barrier op survived the promotion…
    let v = Buffet::with_pid(agent.clone(), 999, Credentials::root());
    for (path, body) in &acked_alive {
        let st = v
            .stat(path)
            .unwrap_or_else(|e| panic!("acked {path} lost across failover: {e:?}"));
        assert_eq!(st.kind, FileKind::Regular);
        if !body.is_empty() {
            assert_eq!(&v.get(path, 1 << 16).unwrap(), body, "{path} bytes diverged");
        }
    }
    // …acked unlinks stayed unlinked…
    for path in &acked_removed {
        assert_eq!(v.stat(path).unwrap_err(), FsError::NotFound, "acked unlink of {path} undone");
    }
    // …and nothing applied twice: every surviving name is unique
    for k in 0..4 {
        let listing = v.readdir(&format!("/d{k}")).unwrap();
        let mut seen = HashSet::new();
        for e in &listing {
            assert!(seen.insert(e.name.clone()), "duplicate entry {} in /d{k}", e.name);
            assert!(!is_provisional(e.ino));
        }
    }
    let _ = std::fs::remove_dir_all(&pdir);
    let _ = std::fs::remove_dir_all(&bdir);
}
