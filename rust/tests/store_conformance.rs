//! Shared conformance suite for the object-store backends: every case
//! runs against both `MemData` and the on-disk `DiskData`, asserting
//! byte-identical semantics for the patterns the client page cache
//! relies on — holes, truncate-then-extend zero fill, short reads at
//! EOF, and page-boundary read-modify-write.

use buffetfs::store::data::{DiskData, MemData};
use buffetfs::store::ObjectStore;

const PAGE: u64 = 4096;

fn with_backends(name: &str, case: impl Fn(&str, &dyn ObjectStore)) {
    let mem = MemData::new();
    case("MemData", &mem);
    let dir = std::env::temp_dir().join(format!(
        "buffetfs-conformance-{}-{name}",
        std::process::id()
    ));
    let disk = DiskData::new(&dir).unwrap();
    case("DiskData", &disk);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn holes_read_as_zeros_across_page_boundaries() {
    with_backends("holes", |be, s| {
        // write only in pages 0 and 2, leaving page 1 a hole
        s.write(1, 0, b"head").unwrap();
        let tail_off = 2 * PAGE + 10;
        s.write(1, tail_off, b"tail").unwrap();
        // the hole page reads as zeros
        let hole = s.read(1, PAGE, PAGE as u32).unwrap();
        assert_eq!(hole, vec![0u8; PAGE as usize], "{be}: hole page must be zeros");
        // a read straddling data → hole → data
        let all = s.read(1, 0, (3 * PAGE) as u32).unwrap();
        assert_eq!(&all[..4], b"head", "{be}");
        assert!(all[4..tail_off as usize].iter().all(|&b| b == 0), "{be}: gap is zeros");
        assert_eq!(&all[tail_off as usize..tail_off as usize + 4], b"tail", "{be}");
        assert_eq!(all.len(), tail_off as usize + 4, "{be}: short read at EOF");
    });
}

#[test]
fn truncate_then_extend_zero_fills() {
    with_backends("trunc-extend", |be, s| {
        s.write(7, 0, &[0xAB; 2 * PAGE as usize]).unwrap();
        s.truncate(7, 100).unwrap();
        assert_eq!(s.read(7, 0, 4096).unwrap().len(), 100, "{be}: shrunk");
        // extend past a page boundary: everything beyond 100 is zeros,
        // including bytes that held 0xAB before the shrink
        s.truncate(7, PAGE + 200).unwrap();
        let back = s.read(7, 0, (2 * PAGE) as u32).unwrap();
        assert_eq!(back.len(), PAGE as usize + 200, "{be}");
        assert!(back[..100].iter().all(|&b| b == 0xAB), "{be}: surviving prefix");
        assert!(
            back[100..].iter().all(|&b| b == 0),
            "{be}: truncate-then-extend must not resurrect old bytes"
        );
        // extending write after a shrink behaves the same
        s.truncate(7, 0).unwrap();
        s.write(7, 50, b"x").unwrap();
        let back = s.read(7, 0, 100).unwrap();
        assert_eq!(back.len(), 51, "{be}");
        assert!(back[..50].iter().all(|&b| b == 0), "{be}");
        assert_eq!(back[50], b'x', "{be}");
    });
}

#[test]
fn short_reads_at_eof_and_beyond() {
    with_backends("eof", |be, s| {
        let size = PAGE as usize + 123; // EOF mid-page
        s.write(3, 0, &vec![0x5A; size]).unwrap();
        // read exactly to EOF
        assert_eq!(s.read(3, 0, size as u32).unwrap().len(), size, "{be}");
        // ask for more than exists: short read, no padding
        assert_eq!(s.read(3, PAGE, PAGE as u32).unwrap().len(), 123, "{be}");
        // read starting exactly at EOF and far beyond: empty, not error
        assert_eq!(s.read(3, size as u64, 10).unwrap(), Vec::<u8>::new(), "{be}");
        assert_eq!(s.read(3, 99 * PAGE, 10).unwrap(), Vec::<u8>::new(), "{be}");
        // zero-length read anywhere is empty
        assert_eq!(s.read(3, 5, 0).unwrap(), Vec::<u8>::new(), "{be}");
        // a missing object reads empty
        assert_eq!(s.read(999, 0, 10).unwrap(), Vec::<u8>::new(), "{be}");
    });
}

#[test]
fn page_boundary_read_modify_write() {
    with_backends("rmw", |be, s| {
        // base: two full pages of a marker
        s.write(5, 0, &[0x11; 2 * PAGE as usize]).unwrap();
        // overwrite a range straddling the page boundary
        s.write(5, PAGE - 6, &[0x22; 12]).unwrap();
        let back = s.read(5, 0, (2 * PAGE) as u32).unwrap();
        assert!(back[..PAGE as usize - 6].iter().all(|&b| b == 0x11), "{be}");
        assert!(
            back[PAGE as usize - 6..PAGE as usize + 6].iter().all(|&b| b == 0x22),
            "{be}: straddling overwrite"
        );
        assert!(back[PAGE as usize + 6..].iter().all(|&b| b == 0x11), "{be}");
        // sub-page overwrite deep inside one page
        s.write(5, 100, &[0x33; 8]).unwrap();
        let back = s.read(5, 96, 16).unwrap();
        assert_eq!(&back[..4], &[0x11; 4], "{be}");
        assert_eq!(&back[4..12], &[0x33; 8], "{be}");
        assert_eq!(&back[12..], &[0x11; 4], "{be}");
        // an extending write whose start is inside the last page
        s.write(5, 2 * PAGE - 4, &[0x44; 8]).unwrap();
        let back = s.read(5, 2 * PAGE - 4, 100).unwrap();
        assert_eq!(back, vec![0x44; 8], "{be}: extension is visible and short-read");
    });
}

#[test]
fn interleaved_extents_match_oracle() {
    // a randomized mirror check: apply the same writes to the backend
    // and to a Vec<u8> oracle, compare page-aligned and unaligned reads
    with_backends("oracle", |be, s| {
        let mut oracle: Vec<u8> = Vec::new();
        let mut seed: u64 = 0x9E3779B97F4A7C15;
        let mut rng = move || {
            seed ^= seed << 13;
            seed ^= seed >> 7;
            seed ^= seed << 17;
            seed
        };
        for _ in 0..200 {
            let off = rng() % (16 * PAGE);
            let len = (rng() % 600 + 1) as usize;
            let byte = (rng() % 256) as u8;
            let data = vec![byte; len];
            s.write(9, off, &data).unwrap();
            let need = off as usize + len;
            if oracle.len() < need {
                oracle.resize(need, 0);
            }
            oracle[off as usize..need].copy_from_slice(&data);
        }
        for probe in 0..32 {
            let off = probe * PAGE / 2;
            let got = s.read(9, off, PAGE as u32).unwrap();
            let want_end = (off as usize + PAGE as usize).min(oracle.len());
            let want = if (off as usize) < oracle.len() {
                &oracle[off as usize..want_end]
            } else {
                &[][..]
            };
            assert_eq!(got, want, "{be}: probe at {off}");
        }
        // delete is idempotent and a recreated object starts empty
        s.delete(9).unwrap();
        s.delete(9).unwrap();
        assert_eq!(s.read(9, 0, 10).unwrap(), Vec::<u8>::new(), "{be}");
        s.write(9, 0, b"new").unwrap();
        assert_eq!(s.read(9, 0, 10).unwrap(), b"new", "{be}");
    });
}
