//! The wire protocol over a real TCP socket: a BServer behind
//! `TcpServer`, driven by `TcpTransport` clients (what `buffetfs serve` /
//! `buffetfs client` deploy).

use std::sync::Arc;

use buffetfs::metrics::RpcMetrics;
use buffetfs::server::BServer;
use buffetfs::store::data::MemData;
use buffetfs::store::fs::LocalFs;
use buffetfs::transport::tcp::{ReconnectConfig, ReconnectTransport, TcpServer, TcpTransport};
use buffetfs::transport::Transport;
use buffetfs::types::{Credentials, FileKind, Ino};
use buffetfs::wire::{OpenCtx, Request, Response};

fn spawn_server() -> (TcpServer, std::net::SocketAddr) {
    let fs = LocalFs::new(0, 0, Box::new(MemData::new()));
    let server = BServer::new(fs);
    let tcp = TcpServer::spawn("127.0.0.1:0", server).expect("bind");
    let addr = tcp.local_addr;
    (tcp, addr)
}

#[test]
fn full_file_cycle_over_tcp() {
    let (server, addr) = spawn_server();
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect(addr, metrics.clone()).unwrap();
    let root = Ino::new(0, 0, 1);
    let cred = Credentials::root();

    // create
    let resp = t
        .call(Request::Create {
            dir: root,
            name: "net.dat".into(),
            mode: 0o644,
            kind: FileKind::Regular,
            cred: cred.clone(),
            client: 1,
        })
        .unwrap();
    let ino = match resp {
        Response::Created(e) => e.ino,
        other => panic!("{other:?}"),
    };

    // write with deferred-open ctx (the BuffetFS schedule over real TCP)
    let ctx = OpenCtx { client: 1, handle: 99, flags: buffetfs::types::OpenFlags::RDWR, cred: cred.clone() };
    let resp = t
        .call(Request::Write { ino, off: 0, data: b"over the wire".to_vec(), open_ctx: Some(ctx) })
        .unwrap();
    assert!(matches!(resp, Response::Written { written: 13, .. }));

    // read it back
    match t.call(Request::Read { ino, off: 5, len: 32, open_ctx: None }).unwrap() {
        Response::Data { data, .. } => assert_eq!(data, b"the wire"),
        other => panic!("{other:?}"),
    }

    // close wrap-up
    assert_eq!(t.call(Request::Close { ino, client: 1, handle: 99 }).unwrap(), Response::Unit);
    assert_eq!(metrics.total_rpcs(), 4);
    server.shutdown();
}

#[test]
fn errors_cross_the_wire_intact() {
    let (server, addr) = spawn_server();
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect(addr, metrics).unwrap();
    let root = Ino::new(0, 0, 1);
    let err = t
        .call(Request::Lookup { dir: root, name: "ghost".into(), cred: Credentials::root() })
        .unwrap_err();
    assert_eq!(err, buffetfs::error::FsError::NotFound);
    // stale version
    let err = t.call(Request::GetAttr { ino: Ino::new(0, 7, 1) }).unwrap_err();
    assert_eq!(err, buffetfs::error::FsError::Stale);
    server.shutdown();
}

#[test]
fn dead_peer_times_out_instead_of_hanging_forever() {
    use std::time::{Duration, Instant};
    // a "server" that accepts the connection and then never answers
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let hold = std::thread::spawn(move || {
        let conn = listener.accept();
        std::thread::sleep(Duration::from_millis(800));
        drop(conn);
    });
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect_with_timeout(
        addr,
        Some(Duration::from_millis(150)),
        metrics,
    )
    .unwrap();
    assert_eq!(t.read_timeout(), Some(Duration::from_millis(150)));
    let t0 = Instant::now();
    let err = t.call(Request::GetAttr { ino: Ino::new(0, 0, 1) }).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_millis(700),
        "the call must fail within the configured timeout, not hang"
    );
    match err {
        buffetfs::error::FsError::Transport(msg) => {
            assert!(msg.contains("timed out"), "unexpected error text: {msg}")
        }
        other => panic!("expected a transport timeout, got {other:?}"),
    }
    // the stream is desynchronized: the transport poisons itself so a
    // later call can never receive the stale (mismatched) response
    assert!(t.is_poisoned());
    let t1 = Instant::now();
    let err = t.call(Request::GetAttr { ino: Ino::new(0, 0, 1) }).unwrap_err();
    assert!(t1.elapsed() < Duration::from_millis(50), "poisoned calls fail fast");
    match err {
        buffetfs::error::FsError::Transport(msg) => {
            assert!(msg.contains("poisoned"), "unexpected error text: {msg}")
        }
        other => panic!("expected a poisoned-transport error, got {other:?}"),
    }
    hold.join().unwrap();
}

#[test]
fn reconnect_transport_redials_after_peer_death() {
    use std::time::Duration;
    let (server, saddr) = spawn_server();
    // flaky front door: kills its FIRST accepted connection outright
    // (the simulated crash), then proxies later ones to the real server
    // byte-for-byte — so the redial lands on a live peer at the SAME
    // address without racing a listener rebind.
    let front = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let faddr = front.local_addr().unwrap();
    let proxy = std::thread::spawn(move || {
        drop(front.accept());
        let (client_side, _) = front.accept().unwrap();
        let server_side = std::net::TcpStream::connect(saddr).unwrap();
        let mut up_rx = client_side.try_clone().unwrap();
        let mut up_tx = server_side.try_clone().unwrap();
        let up = std::thread::spawn(move || {
            let _ = std::io::copy(&mut up_rx, &mut up_tx);
        });
        let (mut down_rx, mut down_tx) = (server_side, client_side);
        let _ = std::io::copy(&mut down_rx, &mut down_tx);
        let _ = up.join();
    });
    let metrics = Arc::new(RpcMetrics::new());
    let cfg = ReconnectConfig {
        backoff: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(50),
        ..ReconnectConfig::default()
    };
    let t = ReconnectTransport::connect(&faddr.to_string(), cfg, metrics.clone()).unwrap();
    // the first call hits the killed connection and surfaces a transport
    // error — the wrapper never blind-retries the request itself
    // (idempotence is the caller's judgement, not the byte pipe's)
    let err = t.call(Request::GetAttr { ino: Ino::new(0, 0, 1) }).unwrap_err();
    assert!(matches!(err, buffetfs::error::FsError::Transport(_)), "{err:?}");
    // the NEXT call redials through the wrapper and succeeds
    match t.call(Request::GetAttr { ino: Ino::new(0, 0, 1) }) {
        Ok(Response::AttrR(a)) => assert_eq!(a.ino, Ino::new(0, 0, 1)),
        other => panic!("expected attr after redial, got {other:?}"),
    }
    assert_eq!(metrics.reconnects(), 1, "exactly one successful redial recorded");
    drop(t);
    let _ = proxy.join();
    server.shutdown();
}

#[test]
fn pipelined_reconnect_fails_inflight_cleanly_and_rehandshakes() {
    use std::io::{Read, Write};
    use std::time::Duration;
    // Forward exactly `n` length-prefixed frames from src to dst.
    fn forward_frames(
        src: &mut std::net::TcpStream,
        dst: &mut std::net::TcpStream,
        n: usize,
    ) -> std::io::Result<()> {
        for _ in 0..n {
            let mut len = [0u8; 4];
            src.read_exact(&mut len)?;
            let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
            src.read_exact(&mut buf)?;
            dst.write_all(&len)?;
            dst.write_all(&buf)?;
            dst.flush()?;
        }
        Ok(())
    }

    let (server, saddr) = spawn_server();
    // Frame-counting front door: connection 1 relays the pipelined Hello
    // reply plus ONE response, then cuts mid-burst — a crash with
    // requests in flight. Connection 2 (the redial) proxies fully.
    let front = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let faddr = front.local_addr().unwrap();
    let proxy = std::thread::spawn(move || {
        {
            let (client_side, _) = front.accept().unwrap();
            let server_side = std::net::TcpStream::connect(saddr).unwrap();
            let mut up_rx = client_side.try_clone().unwrap();
            let mut up_tx = server_side.try_clone().unwrap();
            let up = std::thread::spawn(move || {
                let _ = std::io::copy(&mut up_rx, &mut up_tx);
            });
            let (mut down_rx, mut down_tx) = (server_side, client_side);
            let _ = forward_frames(&mut down_rx, &mut down_tx, 2);
            let _ = down_tx.shutdown(std::net::Shutdown::Both);
            let _ = down_rx.shutdown(std::net::Shutdown::Both);
            let _ = up.join();
        }
        // the redialed connection gets a faithful byte pipe
        let (client_side, _) = front.accept().unwrap();
        let server_side = std::net::TcpStream::connect(saddr).unwrap();
        let mut up_rx = client_side.try_clone().unwrap();
        let mut up_tx = server_side.try_clone().unwrap();
        let up = std::thread::spawn(move || {
            let _ = std::io::copy(&mut up_rx, &mut up_tx);
        });
        let (mut down_rx, mut down_tx) = (server_side, client_side);
        let _ = std::io::copy(&mut down_rx, &mut down_tx);
        let _ = up.join();
    });

    let metrics = Arc::new(RpcMetrics::new());
    let cfg = ReconnectConfig {
        pipelined: true,
        backoff: Duration::from_millis(5),
        backoff_cap: Duration::from_millis(50),
        ..ReconnectConfig::default()
    };
    let t = ReconnectTransport::connect(&faddr.to_string(), cfg, metrics.clone()).unwrap();
    assert!(t.current().is_pipelined_mode(), "handshake must negotiate pipelined framing");

    // three requests in flight on one connection when the peer dies:
    // exactly one response frame got through before the cut
    let root = Ino::new(0, 0, 1);
    let pendings: Vec<_> = (0..3).map(|_| t.submit(Request::GetAttr { ino: root })).collect();
    let results: Vec<_> = pendings.into_iter().map(|p| p.and_then(|p| t.wait(p))).collect();
    let ok = results.iter().filter(|r| r.is_ok()).count();
    assert_eq!(ok, 1, "exactly the forwarded response completes: {results:?}");
    for r in &results {
        if let Err(e) = r {
            assert!(
                matches!(e, buffetfs::error::FsError::Transport(_)),
                "in-flight requests must fail cleanly with a transport error, got {e:?}"
            );
        }
    }

    // the next call redials through the wrapper, re-handshakes Hello on
    // the fresh connection, and lands back in pipelined mode
    match t.call(Request::GetAttr { ino: root }) {
        Ok(Response::AttrR(a)) => assert_eq!(a.ino, root),
        other => panic!("expected attr after redial, got {other:?}"),
    }
    assert_eq!(metrics.reconnects(), 1, "exactly one successful redial recorded");
    assert!(t.current().is_pipelined_mode(), "redial must re-negotiate pipelined framing");
    drop(t);
    let _ = proxy.join();
    server.shutdown();
}

#[test]
fn multiple_concurrent_tcp_clients() {
    let (server, addr) = spawn_server();
    let root = Ino::new(0, 0, 1);
    std::thread::scope(|scope| {
        for w in 0..4 {
            scope.spawn(move || {
                let metrics = Arc::new(RpcMetrics::new());
                let t = TcpTransport::connect(addr, metrics).unwrap();
                let cred = Credentials::root();
                for i in 0..10 {
                    let name = format!("c{w}-{i}");
                    let resp = t
                        .call(Request::Create {
                            dir: root,
                            name,
                            mode: 0o644,
                            kind: FileKind::Regular,
                            cred: cred.clone(),
                            client: w,
                        })
                        .unwrap();
                    let ino = match resp {
                        Response::Created(e) => e.ino,
                        other => panic!("{other:?}"),
                    };
                    t.call(Request::Write { ino, off: 0, data: vec![w as u8; 64], open_ctx: None })
                        .unwrap();
                }
            });
        }
    });
    // all 40 files landed
    let metrics = Arc::new(RpcMetrics::new());
    let t = TcpTransport::connect(addr, metrics).unwrap();
    match t
        .call(Request::ReadDir { dir: root, client: 9, register: false, cred: Credentials::root() })
        .unwrap()
    {
        Response::Entries { entries, .. } => assert_eq!(entries.len(), 40),
        other => panic!("{other:?}"),
    }
    server.shutdown();
}
